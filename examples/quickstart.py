"""Quickstart: ScalaBFS-on-TPU in five minutes (CPU-runnable).

1. Generate a Graph500 Kronecker graph (the paper's RMAT suite).
2. Run hybrid-mode BFS with the local engine and verify against the
   pure-python oracle.
3. Partition the graph the paper's way (VID % Q) and run the distributed
   engine (1 host device here; the same code drives a 512-chip mesh).
4. Evaluate the paper's §V performance model for this graph.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.compat import make_mesh
from repro.core import (BFSRunner, SchedulerConfig, bfs_oracle,
                        build_local_graph, partition_graph)
from repro.core.bfs_distributed import DistConfig, DistributedBFS
from repro.core.perf_model import perf_total, tpu_model_teps
from repro.graph import get_dataset


def main():
    # -- 1. graph ---------------------------------------------------------
    ds = get_dataset("rmat18-8")          # 2^18 vertices, avg degree ~16
    n, m = ds.csr.num_vertices, ds.csr.indices.size
    deg = np.diff(ds.csr.indptr)
    root = int(np.argmax(deg))
    print(f"graph rmat18-8: |V|={n:,} |E|={m:,} root={root}")

    # -- 2. local hybrid BFS vs oracle -------------------------------------
    g = build_local_graph(ds.csr, ds.csc)
    res = BFSRunner(g, SchedulerConfig(policy="beamer")).run(root)
    oracle = bfs_oracle(ds.csr, root)
    assert np.array_equal(np.minimum(res.level, 1 << 30),
                          np.minimum(oracle, 1 << 30))
    print(f"local hybrid BFS: {res.iterations} iters "
          f"({res.push_iters} push / {res.pull_iters} pull), "
          f"{res.gteps:.4f} GTEPS (CPU), levels match oracle")

    # -- 3. distributed engine (paper §IV) ---------------------------------
    q = 4                                  # 4 PEs on 1 device (PC)
    pg = partition_graph(ds.csr, ds.csc, q)
    mesh = make_mesh((jax.device_count(),), ("data",))
    eng = DistributedBFS(pg, mesh,
                         cfg=DistConfig(dispatch="bitmap", crossbar="flat"))
    lev = eng.run(root)
    assert np.array_equal(np.minimum(lev, 1 << 30),
                          np.minimum(oracle, 1 << 30))
    print(f"distributed BFS (Q={q} shards, {jax.device_count()} device(s)): "
          f"levels match oracle, stats={eng.last_stats}")

    # -- 4. the paper's §V model + TPU re-parameterization ------------------
    len_nl = float(deg[deg > 0].mean())
    u280 = perf_total(2, 32, len_nl) / 1e9
    v5e = tpu_model_teps(32, len_nl) / 1e9
    print(f"§V model, Len_nl={len_nl:.1f}: U280 32PC/64PE -> {u280:.2f} "
          f"GTEPS (paper measures 19.7 peak); v5e 32-chip -> {v5e:.0f} GTEPS")


if __name__ == "__main__":
    main()
