"""Async BFS serving walkthrough: dynamic batching of single-root queries.

A stream of independent `submit(root)` calls — the shape real traffic
arrives in — is coalesced by ``repro.launch.dynbatch.DynamicBatcher`` into
full MS-BFS waves (up to 32 roots = one uint32 plane word per wave), so
every CSR/CSC edge read serves the whole wave.  Three scenes:

1. Deterministic scheduling with an injected fake clock (how the tests
   drive the scheduler: no threads, ``pump()`` by hand).
2. A real threaded batcher serving a burst of clients.
3. Backpressure: the bounded queue rejecting an overload.

  PYTHONPATH=src python examples/serve_bfs_async.py
"""
import numpy as np

from repro.core import MultiSourceBFSRunner, bfs_oracle, build_local_graph
from repro.graph import get_dataset
from repro.launch.dynbatch import DynamicBatcher, QueueFull


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def main():
    ds = get_dataset("small-12-8")
    engine = MultiSourceBFSRunner(build_local_graph(ds.csr, ds.csc))
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(0)
    roots = rng.choice(np.flatnonzero(deg > 0), 48, replace=True)

    # -- 1. deterministic fake-clock mode --------------------------------
    clock = FakeClock()
    batcher = DynamicBatcher(engine, window=0.01, max_batch=32, clock=clock)
    futures = [batcher.submit(int(r), block=False) for r in roots[:5]]
    assert batcher.pump() is None, "window still open -> no wave yet"
    clock.advance(0.02)                      # past the 10 ms window
    wave = batcher.pump()
    print(f"[fake clock] 5 submits -> 1 wave: batch={wave.batch} "
          f"slots={wave.n_slots} iters={wave.iterations} "
          f"teps={wave.aggregate_teps:.0f}")
    ok = all(np.array_equal(f.result(), bfs_oracle(ds.csr, f.root))
             for f in futures)
    print(f"[fake clock] futures match bfs_oracle: {ok}, "
          f"latencies={[f.latency for f in futures]}")
    batcher.close()

    # -- 2. threaded serving (real clock) --------------------------------
    with DynamicBatcher(engine, out_deg=deg, window=0.05) as batcher:
        futures = [batcher.submit(int(r)) for r in roots]
        levels = [f.result(timeout=60.0) for f in futures]
    s = batcher.stats()
    print(f"[threaded] {s['requests']} requests -> {s['waves']} waves "
          f"(mean batch {s['mean_batch']}), p50={s['latency_p50']}s "
          f"p99={s['latency_p99']}s aggregate_teps={s['aggregate_teps']}")
    print(f"[threaded] mean vertices reached per query: "
          f"{np.mean([(l < (1 << 30)).sum() for l in levels]):.0f}")

    # -- 3. backpressure -------------------------------------------------
    batcher = DynamicBatcher(engine, window=1.0, max_pending=4,
                             clock=FakeClock())
    for r in roots[:4]:
        batcher.submit(int(r), block=False)
    try:
        batcher.submit(int(roots[4]), block=False)
    except QueueFull as e:
        print(f"[backpressure] 5th submit rejected: {e}")
    batcher.close(drain=True)                # serves the 4 queued requests
    print(f"[backpressure] drained waves: {batcher.stats()['waves']}")


if __name__ == "__main__":
    main()
