"""End-to-end training example: a ~100M-param llama-family model, a few
hundred steps, with checkpoint/restart and an injected failure.

Uses the same launch.train driver the production entrypoint exposes; on
CPU this takes a while at the full --steps 200, so the default here runs a
smaller budget (override with --steps).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import json

from repro.models.config import ArchConfig
import repro.launch.train as T


# ~100M params: 12L x 768d (GPT-2-small class), llama3-style blocks
EXAMPLE_100M = ArchConfig(
    name="example-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32000, window=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_100m")
    args = ap.parse_args()

    # register the example config so the stock driver can resolve it
    import repro.configs as C
    module = type("M", (), {"CONFIG": EXAMPLE_100M, "REDUCED": EXAMPLE_100M})
    C._MODULES["example-100m"] = module

    n = EXAMPLE_100M.param_count()
    print(f"example-100m: {n/1e6:.1f}M params, steps={args.steps}")
    run = T.RunConfig(
        arch="example-100m", reduced=False, steps=args.steps,
        global_batch=args.global_batch, seq_len=args.seq_len,
        microbatches=2, ckpt_dir=args.ckpt_dir, ckpt_every=20,
        inject_failures=(args.steps // 2,),   # prove restart mid-run
        log_every=5)
    out = T.train(run)
    print(json.dumps({k: v for k, v in out.items() if k != "log"}))
    assert out["restarts"] >= 1, "failure injection did not trigger"
    assert out["final_loss"] < out["first_loss"], "loss did not fall"
    print("OK: loss fell and training survived an injected failure")


if __name__ == "__main__":
    main()
