"""Batched-serving example: prefill + greedy decode on a reduced config,
same serve_step the 32k/500k dry-run cells lower.

  PYTHONPATH=src python examples/serve_lm.py [--arch llama3.2-3b]
"""
import argparse
import json

from repro.launch.serve import greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()
    out = greedy_decode(args.arch, reduced=True, batch=args.batch,
                        prompt_len=args.prompt_len,
                        gen_tokens=args.gen_tokens)
    print(json.dumps(out, indent=2))
    assert out["finite"]
    print("OK: served a batch with finite logits")


if __name__ == "__main__":
    main()
