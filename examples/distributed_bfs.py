"""Distributed-BFS example: the paper's Table II configurations, scaled to
however many host devices exist, with both dispatcher designs.

Shows the full/multi-layer crossbar trade-off the paper measures
(§IV-D): flat = one all-to-all over all devices; staged = one exchange
per mesh axis (the k-layer crossbar).

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/distributed_bfs.py
"""
import time

import numpy as np
import jax

from repro.compat import make_mesh
from repro.core import bfs_oracle, count_traversed_edges, partition_graph
from repro.core.bfs_distributed import DistConfig, DistributedBFS
from repro.core.perf_model import (full_crossbar_fifos,
                                   multilayer_crossbar_fifos)
from repro.graph import get_dataset


def main():
    n_dev = jax.device_count()
    ds = get_dataset("rmat18-16")
    deg = np.diff(ds.csr.indptr)
    root = int(np.argmax(deg))
    oracle = np.minimum(bfs_oracle(ds.csr, root), 1 << 30)

    # 2 PEs per PC, the paper's 32PC/64PE shape (scaled to n_dev PCs)
    q = n_dev * 2
    pg = partition_graph(ds.csr, ds.csc, q)
    if n_dev >= 4:
        mesh = make_mesh((n_dev // 2, 2), ("data", "model"))
    else:
        mesh = make_mesh((n_dev,), ("data",))
    print(f"devices={n_dev} mesh={dict(mesh.shape)} shards={q} (2 PEs/PC)")

    for dispatch, crossbar in (("bitmap", "flat"), ("bitmap", "staged"),
                               ("queue", "flat")):
        eng = DistributedBFS(pg, mesh, cfg=DistConfig(
            dispatch=dispatch, crossbar=crossbar))
        lev = eng.run(root)          # warm-up + correctness
        assert np.array_equal(np.minimum(lev, 1 << 30), oracle)
        t0 = time.perf_counter()
        eng.run(root)
        dt = time.perf_counter() - t0
        trav = int(deg[np.minimum(lev, 1 << 30) < (1 << 30)].sum())
        print(f"  {dispatch:6s}/{crossbar:6s}: ok, {dt:.2f}s, "
              f"{trav/dt/1e9:.4f} GTEPS (CPU), {eng.last_stats}")

    print("crossbar resource model (paper §IV-D):",
          f"64x64 full = {full_crossbar_fifos(64)} FIFOs,",
          f"3-layer 4x4 = {multilayer_crossbar_fifos((4, 4, 4))} FIFOs")

    # batched MS-BFS: 32 concurrent queries share every edge read and every
    # crossbar exchange (one bit-plane per source) — the aggregate-GTEPS
    # serving mode.  Also reachable via repro.launch.serve.bfs_batch.
    rng = np.random.default_rng(0)
    roots = rng.choice(np.flatnonzero(deg > 0), 32, replace=False)
    eng = DistributedBFS(pg, mesh, cfg=DistConfig(dispatch="bitmap",
                                                  crossbar="flat"))
    levels = eng.run_batch(roots)          # warm-up + correctness
    for i, r in enumerate(roots[:4]):      # spot-check vs per-root oracle
        assert np.array_equal(np.minimum(levels[i], 1 << 30),
                              np.minimum(bfs_oracle(ds.csr, int(r)), 1 << 30))
    t0 = time.perf_counter()
    levels = eng.run_batch(roots)
    dt = time.perf_counter() - t0
    trav = count_traversed_edges(deg, levels)
    print(f"  MS-BFS batch=32: ok, {dt:.2f}s, {trav/dt/1e9:.4f} aggregate "
          f"GTEPS (CPU), {eng.last_stats}")


if __name__ == "__main__":
    main()
