"""Fault-tolerance machinery (``repro.ft``): taxonomy, injection,
step timing, retry-from-checkpoint, and the EngineSupervisor policy.

The supervisor is unit-tested against scripted fake engines with backoff
and clocks injected, so every policy branch — transient retry, wave
abandonment, quarantine bisection, budget escalation, the degradation
ladder, and the watchdog — runs deterministically.  The end-to-end chaos
acceptance on a real graph lives in ``tests/test_chaos.py``.
"""
import math
import time

import numpy as np
import pytest

from repro.core import BudgetOverflowError
from repro.ft import (DETERMINISTIC, TRANSIENT, EngineSupervisor,
                      FailureInjector, FaultPlan, FaultyEngine,
                      InjectedFailure, KernelFault, PoisonedRoot,
                      RequestQuarantined, StepTimer, WaveAbandoned,
                      WaveTimeout, classify_fault, find_tunable_engine,
                      is_kernel_fault, run_with_retries,
                      supports_budget_override)

N = 16          # |V| of the fake engines' imaginary graph


class ScriptedEngine:
    """Serves ``levels[i][:] = root`` after raising scripted failures.

    ``script`` is a list consumed one entry per ``run_batch`` call:
    an exception instance to raise, or None to serve.  An exhausted
    script serves.  Records every call's (roots, budget).
    """

    def __init__(self, script=(), stats=None):
        self.script = list(script)
        self.calls = []
        self.last_stats = dict(stats or {})

    def run_batch(self, roots, *, budget=None):
        roots = np.asarray(roots)
        self.calls.append((roots.tolist(), budget))
        if self.script:
            exc = self.script.pop(0)
            if exc is not None:
                raise exc
        return np.repeat(roots[:, None], N, axis=1)


def expected_rows(roots):
    return np.repeat(np.asarray(roots)[:, None], N, axis=1)


def make_supervisor(engine, **kw):
    kw.setdefault("backoff", 0.0)
    kw.setdefault("watchdog", False)
    kw.setdefault("pad_to_plane", False)
    return EngineSupervisor(engine, **kw)


# ---------------------------------------------------------------------------
# taxonomy + helpers
# ---------------------------------------------------------------------------

def test_classify_fault():
    for exc in (ValueError("x"), TypeError("x"), IndexError("x"),
                KeyError("x"), NotImplementedError("x"),
                PoisonedRoot("x")):
        assert classify_fault(exc) == DETERMINISTIC
    for exc in (RuntimeError("x"), InjectedFailure("x"), KernelFault("x"),
                WaveTimeout("x"), OSError("x"), MemoryError("x"),
                BudgetOverflowError(8, 99, 3)):
        assert classify_fault(exc) == TRANSIENT


def test_is_kernel_fault():
    assert is_kernel_fault(KernelFault("boom"))
    assert is_kernel_fault(RuntimeError("pallas lowering failed"))
    assert is_kernel_fault(RuntimeError("XLA compilation error"))
    assert not is_kernel_fault(RuntimeError("disk on fire"))
    # deterministic classes never drive the ladder, whatever they say
    assert not is_kernel_fault(ValueError("pallas pallas pallas"))


def test_supports_budget_override():
    assert supports_budget_override(ScriptedEngine())

    class NoBudget:
        def run_batch(self, roots):
            return roots

    class Kwargs:
        def run_batch(self, roots, **kw):
            return roots

    assert not supports_budget_override(NoBudget())
    assert supports_budget_override(Kwargs())


def test_find_tunable_engine_walks_wrappers():
    class Tunable:
        def __init__(self):
            self.use_pallas = True
            self.packed = True

    class Wrap:
        def __init__(self, inner):
            self.inner = inner

    t = Tunable()
    assert find_tunable_engine(t) is t
    assert find_tunable_engine(Wrap(Wrap(t))) is t
    assert find_tunable_engine(Wrap(object())) is None


# ---------------------------------------------------------------------------
# failures.py primitives
# ---------------------------------------------------------------------------

def test_failure_injector_fires_exactly_once():
    inj = FailureInjector(fail_at=(3, 7))
    inj.check(0)
    with pytest.raises(InjectedFailure):
        inj.check(3)
    inj.check(3)            # second pass over the same step: clean
    with pytest.raises(InjectedFailure):
        inj.check(7)
    inj.check(7)


def test_step_timer_median_and_stragglers():
    t = StepTimer(k=3.0, window=50)
    assert t.median() is None
    for i, d in enumerate([0.1, 0.1, 0.1, 0.1]):
        assert not t.record(i, d)       # < 5 samples: never flagged
    assert t.median() == pytest.approx(0.1)
    assert t.record(4, 1.0)             # 1.0 > 3 x 0.1 with 5 samples
    assert t.flags == [4]
    assert not t.record(5, 0.25)        # above median but under k x


def test_run_with_retries_replays_from_checkpoint(tmp_path):
    """The retry loop against the real checkpoint module: every failure
    restores the latest checkpoint and replays to an exact final state."""
    from repro.ckpt import checkpoint as ckpt

    ckpt_dir = str(tmp_path / "ckpt")
    state = {"x": np.zeros(4, np.int64)}
    executed = []

    def step_fn(step):
        state["x"] = state["x"] + step
        ckpt.save(ckpt_dir, step, {"x": state["x"]})
        executed.append(step)

    def restore_fn():
        s = ckpt.latest_step(ckpt_dir)
        if s is None:
            state["x"] = np.zeros(4, np.int64)
            return 0
        tree, manifest = ckpt.restore(ckpt_dir, s, {"x": state["x"]})
        assert manifest["step"] == s
        state["x"] = np.asarray(tree["x"])
        return s + 1

    timer = StepTimer()
    inj = FailureInjector(fail_at=(0, 3, 5))
    done, restarts = run_with_retries(step_fn, restore_fn, num_steps=8,
                                      injector=inj, timer=timer)
    assert done == 8 and restarts == 3
    # replay is exact: state equals the fault-free accumulation
    np.testing.assert_array_equal(state["x"],
                                  np.full(4, sum(range(8)), np.int64))
    assert len(timer.durations) == len(executed) == 8

    def perma_broken(step):
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):       # exhausted retry budget raises
        run_with_retries(perma_broken, lambda: 0, num_steps=1,
                         max_retries=2)


# ---------------------------------------------------------------------------
# supervisor: retry / abandon
# ---------------------------------------------------------------------------

def test_clean_wave_passes_through():
    eng = ScriptedEngine()
    sup = make_supervisor(eng)
    wave = sup.run_wave([3, 5, 9])
    assert wave.n_ok == 3 and wave.n_failed == 0
    assert wave.traversals == 1 and wave.retries == 0
    np.testing.assert_array_equal(wave.levels(), expected_rows([3, 5, 9]))
    np.testing.assert_array_equal(sup.run_batch([4]), expected_rows([4]))
    assert sup.stats()["waves"] == 2


def test_transient_fault_retries_and_succeeds():
    eng = ScriptedEngine(script=[InjectedFailure("flaky"),
                                 KernelFault("flaky")])
    slept = []
    # jitter=False pins the legacy deterministic exponential schedule;
    # the (default) decorrelated-jitter path has its own divergence test
    sup = make_supervisor(eng, max_retries=2, backoff=0.01,
                          sleep=slept.append, jitter=False)
    wave = sup.run_wave([1, 2])
    assert wave.n_ok == 2
    assert wave.traversals == 3 and wave.retries == 2
    assert wave.fault_waves == 2
    assert slept == [0.01, 0.02]        # exponential backoff, injected sleep
    assert len(eng.calls) == 3


def test_transient_exhaustion_abandons_with_typed_error():
    eng = ScriptedEngine(script=[RuntimeError("down")] * 10)
    sup = make_supervisor(eng, max_retries=2)
    wave = sup.run_wave([1, 2, 3])
    assert wave.n_failed == 3 and wave.traversals == 3
    for o in wave.outcomes:
        assert isinstance(o.error, WaveAbandoned)
        assert isinstance(o.error.__cause__, RuntimeError)
    with pytest.raises(WaveAbandoned):
        wave.levels()
    # run_batch surfaces the same typed error
    eng2 = ScriptedEngine(script=[RuntimeError("down")] * 10)
    with pytest.raises(WaveAbandoned):
        make_supervisor(eng2, max_retries=1).run_batch([1])


def test_zero_retries_means_single_attempt():
    eng = ScriptedEngine(script=[RuntimeError("down")])
    sup = make_supervisor(eng, max_retries=0)
    wave = sup.run_wave([1])
    assert wave.traversals == 1 and wave.n_failed == 1


# ---------------------------------------------------------------------------
# supervisor: quarantine bisection
# ---------------------------------------------------------------------------

class PoisonEngine(ScriptedEngine):
    def __init__(self, poison):
        super().__init__()
        self.poison = int(poison)

    def run_batch(self, roots, *, budget=None):
        if self.poison in np.asarray(roots).tolist():
            self.calls.append((np.asarray(roots).tolist(), budget))
            raise PoisonedRoot(f"root {self.poison}")
        return super().run_batch(roots, budget=budget)


@pytest.mark.parametrize("batch", [2, 8, 32])
def test_bisection_isolates_poison_within_log_bound(batch):
    roots = list(range(batch))
    poison = batch // 2
    eng = PoisonEngine(poison)
    sup = make_supervisor(eng)
    wave = sup.run_wave(roots)
    assert wave.quarantined == [poison]
    assert wave.n_failed == 1 and wave.n_ok == batch - 1
    err = wave.outcomes[poison].error
    assert isinstance(err, RequestQuarantined)
    assert isinstance(err.__cause__, PoisonedRoot)
    for o in wave.outcomes:
        if o.root != poison:
            np.testing.assert_array_equal(o.levels, expected_rows([o.root])[0])
    # the whole point: O(log B) faulted traversals, not O(B)
    assert wave.fault_waves <= math.ceil(math.log2(batch)) + 1
    assert wave.bisections >= 1
    assert sup.stats()["quarantined"] == [poison]


def test_bisection_isolates_multiple_poisons():
    class MultiPoison(ScriptedEngine):
        def run_batch(self, roots, *, budget=None):
            bad = sorted(set(np.asarray(roots).tolist()) & {2, 5})
            if bad:
                raise PoisonedRoot(f"roots {bad}")
            return super().run_batch(roots, budget=budget)

    sup = make_supervisor(MultiPoison())
    wave = sup.run_wave(list(range(8)))
    assert sorted(wave.quarantined) == [2, 5]
    assert wave.n_ok == 6


def test_singleton_deterministic_failure_quarantines_without_bisection():
    eng = ScriptedEngine(script=[ValueError("bad root")])
    sup = make_supervisor(eng)
    wave = sup.run_wave([7])
    assert wave.quarantined == [7] and wave.bisections == 0
    assert isinstance(wave.outcomes[0].error, RequestQuarantined)


# ---------------------------------------------------------------------------
# supervisor: budget escalation
# ---------------------------------------------------------------------------

class OverflowEngine(ScriptedEngine):
    """Overflows until called with budget >= need, then serves and
    reports the settled budget in last_stats (like the real runner)."""

    def __init__(self, need=64):
        super().__init__()
        self.need = int(need)

    def run_batch(self, roots, *, budget=None):
        got = int(budget or 8)
        if got < self.need:
            self.calls.append((np.asarray(roots).tolist(), budget))
            raise BudgetOverflowError(got, self.need, 2)
        self.last_stats = {"overflow_retries": 1, "budget": got}
        return super().run_batch(roots, budget=budget)


def test_budget_overflow_escalates_via_per_wave_override():
    eng = OverflowEngine(need=64)
    sup = make_supervisor(eng, max_retries=5)
    wave = sup.run_wave([1, 2])
    assert wave.n_ok == 2
    # 8 -> 16 -> 32 -> 64: three escalated retries after the bare attempt
    assert [b for _, b in eng.calls] == [None, 16, 32, 64]
    assert wave.budget_escalations == 3
    # the settled budget becomes the hint the next wave starts from
    assert sup.stats()["budget_hint"] == 64
    eng.calls.clear()
    sup.run_wave([3])
    assert [b for _, b in eng.calls] == [64]


def test_budget_escalation_disabled():
    eng = OverflowEngine(need=64)
    sup = make_supervisor(eng, max_retries=2, escalate_budget=False)
    wave = sup.run_wave([1])
    assert wave.n_failed == 1 and wave.budget_escalations == 0
    assert [b for _, b in eng.calls] == [None, None, None]


def test_budget_kwarg_not_forced_on_engines_without_support():
    class NoBudget:
        last_stats = {}

        def run_batch(self, roots):
            return np.repeat(np.asarray(roots)[:, None], N, axis=1)

    sup = make_supervisor(NoBudget())
    sup._budget_hint = 999          # even with a hint pending
    wave = sup.run_wave([1, 2])
    assert wave.n_ok == 2


# ---------------------------------------------------------------------------
# supervisor: degradation ladder
# ---------------------------------------------------------------------------

class LadderEngine(ScriptedEngine):
    """Kernel-faults while ``use_pallas`` is on (a broken toolchain)."""

    def __init__(self):
        super().__init__()
        self.use_pallas = True
        self.packed = True

    def run_batch(self, roots, *, budget=None):
        if self.use_pallas:
            self.calls.append((np.asarray(roots).tolist(), budget))
            raise KernelFault("pallas lowering failed")
        return super().run_batch(roots, budget=budget)


def test_ladder_demotes_pallas_to_jnp_and_restores():
    eng = LadderEngine()
    sup = make_supervisor(eng, max_retries=3)
    wave = sup.run_wave([1, 2])
    assert wave.n_ok == 2
    assert wave.demotions == ["pallas->jnp"]
    # two kernel faults before the demotion kicked in, then success
    assert wave.fault_waves == 2 and wave.traversals == 3
    # knobs restored per-wave by default
    assert eng.use_pallas is True and eng.packed is True


def test_ladder_sticky_demotions_persist():
    eng = LadderEngine()
    sup = make_supervisor(eng, max_retries=3, sticky_demotions=True)
    sup.run_wave([1])
    assert eng.use_pallas is False
    wave2 = sup.run_wave([2])       # already demoted: clean first attempt
    assert wave2.traversals == 1 and wave2.demotions == []
    assert sup.stats()["demotions"] == ["pallas->jnp"]


def test_ladder_second_rung_unpacks():
    class AlwaysKernelFault(ScriptedEngine):
        def __init__(self):
            super().__init__()
            self.use_pallas = True
            self.packed = True
            self.served = False

        def run_batch(self, roots, *, budget=None):
            if self.use_pallas or self.packed:
                raise KernelFault("kernel fault")
            return super().run_batch(roots, budget=budget)

    eng = AlwaysKernelFault()
    sup = make_supervisor(eng, max_retries=5)
    wave = sup.run_wave([4])
    assert wave.n_ok == 1
    assert wave.demotions == ["pallas->jnp", "packed->boolplane"]


def test_ladder_disabled_never_touches_knobs():
    eng = LadderEngine()
    sup = make_supervisor(eng, max_retries=2, degrade=False)
    wave = sup.run_wave([1])
    assert wave.n_failed == 1 and wave.demotions == []
    assert eng.use_pallas is True


def test_demotion_grants_watchdog_slack():
    eng = LadderEngine()
    sup = make_supervisor(eng, max_retries=3, watchdog=True,
                          wave_deadline=1.0, demotion_slack=4.0,
                          sticky_demotions=True)
    assert sup.current_deadline() == pytest.approx(1.0)
    sup.run_wave([1])
    # the demoted rung is slower by construction; the deadline follows
    assert sup.current_deadline() == pytest.approx(4.0)
    # non-sticky supervisors reset the slack with the knobs
    eng2 = LadderEngine()
    sup2 = make_supervisor(eng2, max_retries=3, watchdog=True,
                           wave_deadline=1.0)
    sup2.run_wave([1])
    assert sup2.current_deadline() == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# supervisor: watchdog
# ---------------------------------------------------------------------------

class StallEngine(ScriptedEngine):
    """Stalls (real wall clock) once, then serves instantly."""

    def __init__(self, stall=0.4):
        super().__init__()
        self.stall = stall
        self.stalled = False

    def run_batch(self, roots, *, budget=None):
        if not self.stalled:
            self.stalled = True
            time.sleep(self.stall)
        return super().run_batch(roots, budget=budget)


def test_watchdog_abandons_stuck_wave_and_retry_succeeds():
    eng = StallEngine(stall=0.5)
    sup = EngineSupervisor(eng, max_retries=2, backoff=0.0,
                           wave_deadline=0.1, pad_to_plane=False)
    t0 = time.perf_counter()
    wave = sup.run_wave([1, 2])
    assert wave.n_ok == 2
    assert wave.timeouts == 1 and wave.retries == 1
    # the stuck attempt was abandoned at ~deadline, not ridden out;
    # total time is dominated by joining the zombie, well under 2x stall
    assert time.perf_counter() - t0 < 2.0
    assert sup.stats()["timeouts"] == 1


def test_watchdog_timeout_is_typed_and_exhaustible():
    class AlwaysStuck(ScriptedEngine):
        def run_batch(self, roots, *, budget=None):
            time.sleep(0.3)
            return super().run_batch(roots, budget=budget)

    sup = EngineSupervisor(AlwaysStuck(), max_retries=1, backoff=0.0,
                           wave_deadline=0.05, pad_to_plane=False)
    wave = sup.run_wave([5])
    assert wave.n_failed == 1 and wave.timeouts == 2
    err = wave.outcomes[0].error
    assert isinstance(err, WaveAbandoned)
    assert isinstance(err.__cause__, WaveTimeout)


def test_cold_engine_is_never_deadlined():
    sup = EngineSupervisor(ScriptedEngine(), watchdog=True)
    assert sup.current_deadline() is None       # no history yet
    for _ in range(3):
        sup.run_wave([1])
    dl = sup.current_deadline()                 # k x median, clamped up
    assert dl is not None and dl >= sup.min_deadline


def test_explicit_deadline_beats_derived():
    sup = EngineSupervisor(ScriptedEngine(), wave_deadline=7.5)
    assert sup.current_deadline() == pytest.approx(7.5)
    assert EngineSupervisor(ScriptedEngine(),
                            watchdog=False).current_deadline() is None


# ---------------------------------------------------------------------------
# chaos harness doubles
# ---------------------------------------------------------------------------

def test_fault_plan_exact_once_and_validation():
    plan = FaultPlan([(0, "kernel"), (2, "stuck")])
    assert len(plan) == 2
    assert plan.pop(1) is None
    assert plan.pop(0) == "kernel" and plan.pop(0) is None
    assert plan.pop(2) == "stuck"
    assert plan.injected == [(0, "kernel"), (2, "stuck")]
    assert len(plan) == 0
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan([(0, "gremlins")])
    with pytest.raises(ValueError, match="duplicate"):
        FaultPlan([(0, "kernel"), (0, "runtime")])


def test_fault_plan_random_is_deterministic():
    a = FaultPlan.random(100, 0.2, seed=7)
    b = FaultPlan.random(100, 0.2, seed=7)
    assert a.pending() == b.pending()
    assert 0 < len(a) < 100
    assert FaultPlan.random(100, 0.2, seed=8).pending() != a.pending()
    assert len(FaultPlan.random(100, 0.0, seed=7)) == 0


def test_faulty_engine_injects_per_plan():
    inner = ScriptedEngine()
    naps = []
    eng = FaultyEngine(inner, FaultPlan([(0, "kernel"), (1, "runtime"),
                                         (2, "stuck")]),
                       stall_seconds=9.0, sleep=naps.append)
    with pytest.raises(KernelFault):
        eng.run_batch([1])
    with pytest.raises(InjectedFailure):
        eng.run_batch([1])
    rows = eng.run_batch([1])               # stuck: stalls, then serves
    assert naps == [9.0]
    np.testing.assert_array_equal(rows, expected_rows([1]))
    assert eng.calls == 3 and len(inner.calls) == 1


def test_faulty_engine_poison_and_break_pallas():
    inner = LadderEngine()
    inner.use_pallas = False                # healthy rung
    eng = FaultyEngine(inner, poisoned_roots=[3])
    with pytest.raises(PoisonedRoot):
        eng.run_batch([1, 3])
    np.testing.assert_array_equal(eng.run_batch([1, 2]),
                                  expected_rows([1, 2]))
    inner.use_pallas = True
    broken = FaultyEngine(inner, break_pallas=True)
    with pytest.raises(KernelFault):
        broken.run_batch([1])
    inner.use_pallas = False
    np.testing.assert_array_equal(broken.run_batch([1]),
                                  expected_rows([1]))


def test_supervisor_over_faulty_engine_end_to_end():
    """The full stack on fakes: plan faults + poison, one run_wave."""
    inner = ScriptedEngine()
    # idx 0 raises PoisonedRoot (poison check preempts the plan), so pin
    # the kernel fault to idx 1 — the first clean bisection sub-wave
    eng = FaultyEngine(inner, FaultPlan([(1, "kernel")]),
                       poisoned_roots=[6])
    sup = make_supervisor(eng, max_retries=2)
    wave = sup.run_wave(list(range(8)))
    assert wave.quarantined == [6]
    assert wave.n_ok == 7 and wave.n_failed == 1
    assert eng.plan.injected == [(1, "kernel")]
    assert wave.retries >= 1                # the kernel fault was retried
    assert wave.fault_waves >= 2            # kernel fault + bisection path
    for o in wave.outcomes:
        if o.root != 6:
            np.testing.assert_array_equal(o.levels,
                                          expected_rows([o.root])[0])


def test_per_wave_slo_deadline_overrides_watchdog():
    """run_wave(deadline=) overrides the watchdog for one wave: floored
    at min_deadline, capped by a configured wave_deadline, cleared
    afterwards."""
    sup = EngineSupervisor(ScriptedEngine(), wave_deadline=7.5)
    sup._wave_deadline_override = 0.5
    assert sup.current_deadline() == pytest.approx(
        max(0.5, sup.min_deadline))
    sup._wave_deadline_override = 0.01          # nearly-expired SLO
    assert sup.current_deadline() == pytest.approx(sup.min_deadline)
    sup._wave_deadline_override = 100.0         # lax SLO: config caps it
    assert sup.current_deadline() == pytest.approx(7.5)
    sup._wave_deadline_override = None
    assert sup.current_deadline() == pytest.approx(7.5)


def test_run_wave_deadline_guards_cold_engine():
    """A per-wave SLO deadline arms the watchdog even on a COLD engine
    (no history, no configured wave_deadline — the derived deadline
    would be None): the stalled attempt is abandoned at ~min_deadline
    and the retry serves, instead of riding out the stall."""
    eng = StallEngine(stall=0.5)
    sup = EngineSupervisor(eng, max_retries=2, backoff=0.0,
                           pad_to_plane=False)
    assert sup.current_deadline() is None       # cold, no SLO: unguarded
    wave = sup.run_wave([1, 2], deadline=0.1)   # floored to min_deadline
    assert wave.n_ok == 2
    assert wave.timeouts == 1 and wave.retries == 1
    assert sup._wave_deadline_override is None  # per-wave: cleared
    assert sup.current_deadline() is None       # still cold-derived


def test_jitter_backoff_within_envelope_and_decorrelated():
    """Satellite: decorrelated-jitter retry backoff.  Every jittered
    delay stays inside [backoff, backoff_cap] (next draw additionally
    bounded by 3x the previous delay), and two default-seeded
    supervisors facing the SAME fault schedule back off on DIFFERENT
    schedules — pool workers sharing a fault must not retry in
    lockstep."""
    def run_once(seed=None):
        eng = ScriptedEngine(script=[InjectedFailure("correlated")] * 4)
        sup = make_supervisor(eng, max_retries=4, backoff=0.01,
                              backoff_cap=0.5, sleep=lambda s: None,
                              jitter_seed=seed)
        assert sup.run_wave([1, 2]).n_ok == 2
        return list(sup.backoff_log)

    log = run_once()
    assert len(log) == 4
    assert log[0] == 0.01                   # first retry waits the base
    for prev, d in zip(log, log[1:]):
        assert 0.01 <= d <= min(0.5, 3.0 * max(prev, 0.01 / 3))
    # OS-entropy seeding: two supervisors' schedules diverge
    assert run_once() != run_once()
    # explicit seeding restores determinism (and distinct seeds differ)
    assert run_once(seed=7) == run_once(seed=7)
    assert run_once(seed=7) != run_once(seed=8)
