"""MoE dispatch engines must agree exactly: onehot (GShard baseline) vs
gather (sort-FIFO) vs ep (shard_map expert parallelism, run in a
subprocess with 8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _setup(e=8, k=2, d=32, f=64, b=4, s=24, seed=0):
    p = moe.moe_params(jax.random.key(seed), d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 1), (b, s, d), jnp.float32)
    return p, x


def test_gather_matches_onehot_values_and_grads():
    p, x = _setup()
    y1, a1 = moe.moe_forward(x, p, top_k=2, chunk=16, dispatch="onehot")
    y2, a2 = moe.moe_forward(x, p, top_k=2, chunk=16, dispatch="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert float(abs(a1 - a2)) < 1e-6

    def loss(params, dispatch):
        y, a = moe.moe_forward(x, params, top_k=2, chunk=16,
                               dispatch=dispatch)
        return jnp.sum(y ** 2) + a

    g1 = jax.grad(lambda q: loss(q, "onehot"))(p)
    g2 = jax.grad(lambda q: loss(q, "gather"))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_capacity_drop_semantics_match():
    """Force heavy overflow (tiny capacity) — drop sets must agree."""
    p, x = _setup(e=4, k=2)
    y1, _ = moe.moe_forward(x, p, top_k=2, chunk=16, capacity_factor=0.3,
                            dispatch="onehot")
    y2, _ = moe.moe_forward(x, p, top_k=2, chunk=16, capacity_factor=0.3,
                            dispatch="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_ep_fallback_on_single_device():
    """dispatch='ep' without a mesh falls back to gather (same result)."""
    p, x = _setup()
    y1, a1 = moe.moe_forward(x, p, top_k=2, chunk=16, dispatch="gather")
    y2, a2 = moe.moe_forward(x, p, top_k=2, chunk=16, dispatch="ep")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


_EP_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.models import moe
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    d, f, e, k = 32, 64, 8, 2
    p = moe.moe_params(jax.random.key(0), d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 24, d), jnp.float32)
    y_ref, a_ref = moe.moe_forward(x, p, top_k=k, chunk=16,
                                   dispatch="onehot")
    xs = NamedSharding(mesh, P("data", None, None))
    ps = jax.tree.map(lambda l: NamedSharding(mesh, P()), p)
    for n in ("w_gate", "w_up", "w_down"):
        ps[n] = NamedSharding(mesh, P("model", None, None))

    def f_ep(x, p):
        with compat.use_mesh(mesh):
            return moe.moe_forward(x, p, top_k=k, chunk=16, dispatch="ep")

    y, a = jax.jit(f_ep, in_shardings=(xs, ps))(
        jax.device_put(x, xs), jax.tree.map(jax.device_put, p, ps))
    print(json.dumps(dict(
        err=float(jnp.max(jnp.abs(y_ref - y))),
        aerr=abs(float(a_ref - a)))))
""")


@pytest.mark.slow
def test_ep_matches_onehot_multidevice():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", _EP_SUBPROC],
                       capture_output=True, text=True, timeout=600, env=env)
    assert p.returncode == 0, p.stderr[-2000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-5, out
    assert out["aerr"] < 1e-6, out
