"""System-behaviour tests: Algorithm 2 BFS vs the Algorithm 1 oracle."""
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core import (BFSRunner, SchedulerConfig, bfs_oracle,
                        bfs_reference, build_local_graph, partition_graph)
from repro.core import bitmap
from repro.graph import csr_from_edges, get_dataset, rmat_edges, symmetrize_edges
from repro.graph.csr import transpose_csr

import jax.numpy as jnp


@pytest.fixture(scope="module")
def tiny():
    return get_dataset("tiny-16-4")


@pytest.fixture(scope="module")
def small():
    return get_dataset("small-12-8")


def test_reference_matches_oracle(tiny):
    g = build_local_graph(tiny.csr, tiny.csc)
    for root in [0, 3, 7, 15]:
        got = np.asarray(bfs_reference(g, root)).astype(np.int64)
        np.testing.assert_array_equal(got, bfs_oracle(tiny.csr, root))


@pytest.mark.parametrize("policy", ["push", "pull", "beamer", "paper"])
def test_runner_all_policies(small, policy):
    g = build_local_graph(small.csr, small.csc)
    orc = bfs_oracle(small.csr, 5)
    r = BFSRunner(g, SchedulerConfig(policy=policy)).run(5)
    np.testing.assert_array_equal(r.level.astype(np.int64), orc)


def test_hybrid_inspects_fewer_edges_than_pure_modes(small):
    """Paper Fig. 8: hybrid < push < pull in memory work on scale-free graphs."""
    g = build_local_graph(small.csr, small.csc)
    res = {p: BFSRunner(g, SchedulerConfig(policy=p)).run(2)
           for p in ("push", "pull", "beamer")}
    assert res["beamer"].edges_inspected <= res["push"].edges_inspected
    assert res["beamer"].edges_inspected <= res["pull"].edges_inspected


def test_directed_graph(tiny):
    src, dst = rmat_edges(6, 4, seed=9)
    csr = csr_from_edges(src, dst, 64)
    csc = transpose_csr(csr)
    g = build_local_graph(csr, csc)
    r = BFSRunner(g).run(1)
    np.testing.assert_array_equal(r.level.astype(np.int64), bfs_oracle(csr, 1))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 5), st.booleans())
def test_bfs_property_random_graphs(seed, ef, undirected):
    """Property: Algorithm-2 levels == oracle levels on random RMATs."""
    src, dst = rmat_edges(7, ef, seed=seed)
    if undirected:
        src, dst = symmetrize_edges(src, dst)
    csr = csr_from_edges(src, dst, 128)
    csc = transpose_csr(csr)
    g = build_local_graph(csr, csc)
    root = seed % 128
    r = BFSRunner(g).run(root)
    np.testing.assert_array_equal(r.level.astype(np.int64),
                                  bfs_oracle(csr, root))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=64),
       st.integers(1, 2**20))
def test_bitmap_roundtrip_property(indices, nbits):
    nbits = max(nbits, max(indices) + 1)
    w = bitmap.from_indices_dense(jnp.asarray(np.array(indices)), nbits)
    mask = np.asarray(bitmap.unpack(w, nbits))
    want = np.zeros(bitmap.num_words(nbits) * 32, bool)[:nbits]
    want[np.asarray(indices)] = True
    np.testing.assert_array_equal(mask, want)
    assert int(bitmap.popcount(w)) == int(want.sum())
    got = np.asarray(bitmap.test_bits(w, jnp.asarray(np.array(indices))))
    assert got.all()


def test_bitmap_pack_unpack_inverse():
    rng = np.random.default_rng(0)
    mask = jnp.asarray(rng.random(4096) < 0.3)
    np.testing.assert_array_equal(
        np.asarray(bitmap.unpack(bitmap.pack(mask), 4096)), np.asarray(mask))


def test_partition_preserves_edges(small):
    pg = partition_graph(small.csr, small.csc, 4)
    assert pg.num_edges == small.csr.num_edges
    # every reindexed neighbor maps back to a valid original vertex
    from repro.core.partition import unreindex
    ids = pg.out_indices[pg.out_indices >= 0]
    orig = unreindex(ids.astype(np.int64), pg.num_shards, pg.verts_per_shard)
    assert (orig < small.csr.num_vertices).all()


def test_levels_are_valid_bfs_levels(small):
    """Property: level(child) <= level(parent)+1 along every edge, and every
    reached vertex (level>0) has a parent at level-1."""
    g = build_local_graph(small.csr, small.csc)
    r = BFSRunner(g).run(0)
    lev = r.level.astype(np.int64)
    csr = small.csr
    INF = 2 ** 30
    for v in range(csr.num_vertices):
        if lev[v] >= INF:
            continue
        for u in csr.neighbors(v):
            assert lev[u] <= lev[v] + 1
    csc = small.csc
    for v in range(csr.num_vertices):
        if 0 < lev[v] < INF:
            parents = csc.neighbors(v)
            assert (lev[parents] == lev[v] - 1).any()
