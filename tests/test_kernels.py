"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode).

Sweeps shapes/dtypes per the assignment; property-based bit-level checks via
hypothesis.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from repro.testing import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bitmap_update import bitmap_update
from repro.kernels.csr_gather import gather_pages
from repro.kernels.pull_spmv import pull_spmv_blocks


# ---------------------------------------------------------------------------
# bitmap_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows", [8, 16, 64, 256])
@pytest.mark.parametrize("block_rows", [8, 16])
def test_bitmap_update_shapes(rows, block_rows):
    if rows % block_rows:
        pytest.skip("block must divide rows")
    rng = np.random.default_rng(rows * 31 + block_rows)
    cand = jnp.asarray(rng.integers(0, 2**32, (rows, 128), dtype=np.uint32))
    vis = jnp.asarray(rng.integers(0, 2**32, (rows, 128), dtype=np.uint32))
    nf, vo, cnt = bitmap_update(cand, vis, block_rows=block_rows)
    nf_r, vo_r, cnt_r = ref.bitmap_update_ref(cand, vis)
    np.testing.assert_array_equal(np.asarray(nf), np.asarray(nf_r))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(vo_r))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
def test_bitmap_update_property(seed_a, seed_b):
    rng = np.random.default_rng([seed_a, seed_b])
    cand = jnp.asarray(rng.integers(0, 2**32, (8, 128), dtype=np.uint32))
    vis = jnp.asarray(rng.integers(0, 2**32, (8, 128), dtype=np.uint32))
    nf, vo, cnt = bitmap_update(cand, vis, block_rows=8)
    # invariants: new ∩ visited_in = ∅; visited_out = visited_in ∪ new;
    # count == popcount(new); idempotence on re-application.
    assert int(jnp.sum(jax.lax.population_count(nf & vis))) == 0
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(vis | nf))
    assert int(cnt[0, 0]) == int(
        jnp.sum(jax.lax.population_count(nf).astype(jnp.int32)))
    nf2, vo2, cnt2 = bitmap_update(cand, vo, block_rows=8)
    assert int(cnt2[0, 0]) == 0 and bool((vo2 == vo).all())


def test_fused_frontier_update_flat_odd_sizes():
    for w in [1, 31, 128, 1000, 4096, 5000]:
        rng = np.random.default_rng(w)
        c = jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32))
        v = jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32))
        nf, vo, cnt = ops.fused_frontier_update(c, v)
        np.testing.assert_array_equal(np.asarray(nf), np.asarray(c & ~v))
        np.testing.assert_array_equal(np.asarray(vo), np.asarray(v | (c & ~v)))


def test_pad_rows_to_block_never_degrades_to_one_row():
    """Regression: the old divisor hunt returned block_rows=1 for prime
    row counts (a rows-step grid of 1-row blocks); the pad plan must keep
    full-size blocks and only pad the row count up."""
    assert ops._pad_rows_to_block(17) == (32, 16)       # prime
    assert ops._pad_rows_to_block(16) == (16, 16)       # exact
    assert ops._pad_rows_to_block(5) == (5, 5)          # under the cap
    assert ops._pad_rows_to_block(1) == (1, 1)
    assert ops._pad_rows_to_block(30) == (32, 16)
    for rows in range(1, 200):
        rows_pad, block = ops._pad_rows_to_block(rows)
        assert rows_pad % block == 0 and rows_pad >= rows
        assert block == min(rows, 16)                   # never 1-row-deep


def test_fused_frontier_update_prime_rows_unchanged():
    """Prime row count (w = 17 * 128 -> 17 rows) through both P3 wrappers:
    2-step grid of 16-row blocks, results identical to the jnp oracle."""
    w = 17 * 128
    rng = np.random.default_rng(17)
    c = rng.integers(0, 2**32, (w,), dtype=np.uint32)
    v = rng.integers(0, 2**32, (w,), dtype=np.uint32)
    nf, vo, cnt = ops.fused_frontier_update(jnp.asarray(c), jnp.asarray(v))
    want_new = c & ~v
    np.testing.assert_array_equal(np.asarray(nf), want_new)
    np.testing.assert_array_equal(np.asarray(vo), v | want_new)
    assert int(cnt) == int(np.unpackbits(want_new.view(np.uint8)).sum())
    cb = np.stack([c, rng.integers(0, 2**32, w, dtype=np.uint32)])
    vb = np.stack([v, rng.integers(0, 2**32, w, dtype=np.uint32)])
    nfb, vob, cnts = ops.fused_frontier_update_batch(jnp.asarray(cb),
                                                     jnp.asarray(vb))
    np.testing.assert_array_equal(np.asarray(nfb), cb & ~vb)
    np.testing.assert_array_equal(np.asarray(vob), vb | (cb & ~vb))
    for i in range(2):
        assert int(cnts[i]) == int(
            np.unpackbits((cb[i] & ~vb[i]).view(np.uint8)).sum())


# ---------------------------------------------------------------------------
# msbfs_propagate (fused P2->P3 gather/scatter-OR over packed plane words)
# ---------------------------------------------------------------------------

def _propagate_case(n_rows, nw, m, seed):
    rng = np.random.default_rng(seed)
    frontier = rng.integers(0, 2**32, (n_rows, nw), dtype=np.uint32)
    frontier[-1] = 0                       # trash-row contract
    seen = rng.integers(0, 2**32, (n_rows, nw), dtype=np.uint32)
    seen[-1] = 0xFFFFFFFF
    src = rng.integers(0, n_rows, m, dtype=np.int32)   # duplicates likely
    tgt = rng.integers(0, n_rows, m, dtype=np.int32)
    return (jnp.asarray(frontier), jnp.asarray(seen),
            jnp.asarray(src), jnp.asarray(tgt))


@pytest.mark.parametrize("n_rows,nw,m,block", [
    (33, 1, 64, 64), (65, 2, 128, 32), (129, 1, 256, 256), (17, 3, 96, 16),
])
def test_msbfs_propagate_parity(n_rows, nw, m, block):
    """Kernel vs the jnp per-bit-plane oracle (bitmap._scatter_or_rows)."""
    from repro.kernels.msbfs_propagate import msbfs_propagate_planes
    frontier, seen, src, tgt = _propagate_case(n_rows, nw, m, seed=m + nw)
    got = msbfs_propagate_planes(frontier, seen, src, tgt,
                                 block_edges=block, interpret=True)
    want = ref.msbfs_propagate_planes_ref(frontier, seen, src, tgt)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("op", ["or", "max"])
def test_msbfs_propagate_combine_op_parity(op):
    """Generalized combine: the kernel's op must match the oracle's, on a
    case where the two combines genuinely disagree (duplicate targets
    with word values whose OR is not their max)."""
    from repro.kernels.msbfs_propagate import msbfs_propagate_planes
    frontier, seen, src, tgt = _propagate_case(65, 2, 192, seed=21)
    # force colliding targets so OR-accumulation != max-selection
    tgt = tgt.at[: 64].set(tgt[0])
    got = msbfs_propagate_planes(frontier, seen, src, tgt,
                                 block_edges=32, interpret=True, op=op)
    want = ref.msbfs_propagate_planes_ref(frontier, seen, src, tgt, op=op)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    other = ref.msbfs_propagate_planes_ref(
        frontier, seen, src, tgt, op="max" if op == "or" else "or")
    assert not np.array_equal(np.asarray(want[0]), np.asarray(other[0]))


def test_msbfs_propagate_rejects_unknown_op():
    from repro.kernels.msbfs_propagate import msbfs_propagate_planes
    frontier, seen, src, tgt = _propagate_case(17, 1, 8, seed=1)
    with pytest.raises(ValueError, match="op"):
        msbfs_propagate_planes(frontier, seen, src, tgt, interpret=True,
                               op="xor")
    with pytest.raises(ValueError, match="op"):
        ref.msbfs_propagate_planes_ref(frontier, seen, src, tgt, op="xor")


def test_msbfs_propagate_parity_noninterpret():
    """Non-interpret arm of the parity harness (TPU-only compile)."""
    if jax.default_backend() != "tpu":
        pytest.skip("non-interpret Pallas path needs a TPU backend")
    from repro.kernels.msbfs_propagate import msbfs_propagate_planes
    frontier, seen, src, tgt = _propagate_case(65, 1, 128, seed=0)
    got = msbfs_propagate_planes(frontier, seen, src, tgt,
                                 block_edges=64, interpret=False)
    want = ref.msbfs_propagate_planes_ref(frontier, seen, src, tgt)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_msbfs_propagate_wrapper_masks_and_pads():
    """ops.msbfs_propagate: invalid / OOR edges drop, count is exact, and
    the scatter-OR matches a per-edge numpy loop (independent oracle)."""
    rng = np.random.default_rng(5)
    n, nw, m = 50, 2, 777                  # m not a block multiple
    frontier = rng.integers(0, 2**32, (n, nw), dtype=np.uint32)
    seen = rng.integers(0, 2**32, (n, nw), dtype=np.uint32)
    src = rng.integers(-2, n + 3, m).astype(np.int32)
    tgt = rng.integers(-2, n + 3, m).astype(np.int32)
    valid = rng.random(m) < 0.7
    new, vout, cnt = ops.msbfs_propagate(
        jnp.asarray(frontier), jnp.asarray(seen), jnp.asarray(src),
        jnp.asarray(tgt), jnp.asarray(valid), block_edges=128)
    cand = np.zeros_like(frontier)
    for e in range(m):
        if valid[e] and 0 <= src[e] < n and 0 <= tgt[e] < n:
            cand[tgt[e]] |= frontier[src[e]]
    want_new = cand & ~seen
    np.testing.assert_array_equal(np.asarray(new), want_new)
    np.testing.assert_array_equal(np.asarray(vout), seen | want_new)
    assert int(cnt) == int(np.unpackbits(want_new.view(np.uint8)).sum())


def test_msbfs_propagate_small_budgets_single_compile():
    """Regression: tiny edge budgets (m < block_edges) used to bake the
    raw m into the static block size, compiling a fresh pallas_call per
    distinct small m.  All small budgets must now pad up to ONE fixed
    block shape — exactly one jit cache entry across differing waves."""
    from repro.kernels.msbfs_propagate import msbfs_propagate_planes
    if not (hasattr(msbfs_propagate_planes, "clear_cache")
            and hasattr(msbfs_propagate_planes, "_cache_size")):
        pytest.skip("jit cache introspection unavailable on this JAX")
    msbfs_propagate_planes.clear_cache()
    n, nw = 12, 1
    rng = np.random.default_rng(2)
    f = jnp.asarray(rng.integers(0, 2**32, (n, nw), dtype=np.uint32))
    s = jnp.zeros((n, nw), jnp.uint32)
    outs = {}
    for m in (3, 7, 13, 50, 640):
        src = jnp.arange(m, dtype=jnp.int32) % n
        tgt = (jnp.arange(m, dtype=jnp.int32) * 3) % n
        outs[m] = ops.msbfs_propagate(f, s, src, tgt,
                                      jnp.ones((m,), bool), interpret=True)
    assert msbfs_propagate_planes._cache_size() == 1
    # and the padded runs still match the per-edge oracle
    for m, (new, vout, cnt) in outs.items():
        cand = np.zeros((n, nw), np.uint32)
        for e in range(m):
            cand[(e * 3) % n] |= np.asarray(f)[e % n]
        np.testing.assert_array_equal(np.asarray(new), cand)
        assert int(cnt) == int(np.unpackbits(cand.view(np.uint8)).sum())


def test_scatter_or_rows_matches_loop():
    """bitmap._scatter_or_rows (the jnp fallback): duplicates OR together,
    OOR rows (negative or >= r) drop, existing bits survive."""
    from repro.core import bitmap
    rng = np.random.default_rng(11)
    r, nw, m = 40, 3, 500
    words = rng.integers(0, 2**32, (r, nw), dtype=np.uint32)
    idx = rng.integers(-4, r + 6, m).astype(np.int32)
    msg = rng.integers(0, 2**32, (m, nw), dtype=np.uint32)
    want = words.copy()
    for e in range(m):
        if 0 <= idx[e] < r:
            want[idx[e]] |= msg[e]
    got = bitmap._scatter_or_rows(jnp.asarray(words), jnp.asarray(idx),
                                  jnp.asarray(msg))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_segment_or_rows_matches_loop():
    """bitmap.segment_or_rows: inclusive segmented OR scan over packed
    rows (the scan-based pull propagate's reduction primitive)."""
    from repro.core import bitmap
    rng = np.random.default_rng(13)
    e_, nw = 300, 2
    msg = rng.integers(0, 2**32, (e_, nw), dtype=np.uint32)
    first = np.zeros(e_, bool)
    first[np.sort(rng.choice(e_, 25, replace=False))] = True
    first[0] = True
    got = np.asarray(bitmap.segment_or_rows(jnp.asarray(msg),
                                            jnp.asarray(first)))
    want = np.zeros_like(msg)
    cur = np.zeros(nw, np.uint32)
    for e in range(e_):
        cur = msg[e].copy() if first[e] else (cur | msg[e])
        want[e] = cur
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# csr_gather (HBM reader)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_pages,page,m", [
    (8, 128, 4), (32, 256, 17), (64, 512, 64), (128, 128, 1),
])
def test_gather_pages(num_pages, page, m):
    rng = np.random.default_rng(num_pages + page + m)
    edges = jnp.asarray(
        rng.integers(0, 10**6, (num_pages, page), dtype=np.int32))
    pids = jnp.asarray(rng.integers(0, num_pages, (m,), dtype=np.int32))
    out = gather_pages(edges, pids)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.gather_pages_ref(edges, pids)))


def test_page_table_covers_all_neighbor_lists():
    rng = np.random.default_rng(7)
    page = 64
    degrees = rng.integers(0, 200, 50)
    starts = np.concatenate([[0], np.cumsum(degrees)[:-1]])
    total = int(degrees.sum())
    edges = rng.integers(0, 1000, ((total + page - 1) // page) * page,
                         dtype=np.int32)
    pids, owner, offs = ops.build_page_table(starts, degrees, page, 512)
    got = np.asarray(ops.read_neighbor_pages(jnp.asarray(edges),
                                             jnp.asarray(pids), page))
    # reassemble each vertex's list from its fetched pages and compare
    for v in range(50):
        if degrees[v] == 0:
            continue
        items = [i for i in range(len(owner)) if owner[i] == v]
        parts = []
        need = degrees[v]
        for j, i in enumerate(items):
            lo = offs[i]
            take = min(need, page - lo)
            parts.append(got[i][lo: lo + take])
            need -= take
        want = edges[starts[v]: starts[v] + degrees[v]]
        np.testing.assert_array_equal(np.concatenate(parts), want)


# ---------------------------------------------------------------------------
# pull_spmv (MXU boolean SpMV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,lanes", [(128, 1), (128, 8), (128, 128), (256, 4)])
@pytest.mark.parametrize("density", [0.01, 0.2])
def test_pull_spmv(b, lanes, density):
    rng = np.random.default_rng(b + lanes)
    nb, rb, cb = 12, 4, 4
    blocks = jnp.asarray((rng.random((nb, b, b)) < density)
                         .astype(np.float32)).astype(jnp.bfloat16)
    brow = jnp.asarray(np.sort(rng.integers(0, rb, nb)).astype(np.int32))
    bcol = jnp.asarray(rng.integers(0, cb, nb, dtype=np.int32))
    f = jnp.asarray((rng.random((cb, b, lanes)) < 0.3)
                    .astype(np.float32)).astype(jnp.bfloat16)
    got = ops.pull_spmv(blocks, brow, bcol, f, rb)
    want = ref.pull_spmv_blocks_ref(blocks, brow, bcol, None, f, rb) > 0
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pull_spmv_is_boolean_semiring():
    """OR-AND semiring result == reachability through one block step."""
    rng = np.random.default_rng(3)
    b = 128
    a_np = (rng.random((b, b)) < 0.05)
    f_np = (rng.random((b, 1)) < 0.5)
    blocks = jnp.asarray(a_np[None].astype(np.float32)).astype(jnp.bfloat16)
    f = jnp.asarray(f_np[None].astype(np.float32)).astype(jnp.bfloat16)
    got = np.asarray(ops.pull_spmv(blocks, jnp.zeros(1, jnp.int32),
                                   jnp.zeros(1, jnp.int32), f, 1))[0, :, 0]
    want = (a_np @ f_np.astype(np.int64))[:, 0] > 0
    np.testing.assert_array_equal(got, want)
