"""Tiled-vs-whole-VMEM differential suite for the fused P2->P3 propagate.

The row-tiled kernel (``msbfs_propagate_planes_tiled`` + the edge
bucketing in ``kernels.ops``) must be bit-exact against BOTH the
whole-VMEM kernel and the pure-jnp oracle on every case the tiling could
plausibly break: targets straddling tile boundaries, hub vertices whose
edges span / concentrate on tiles, batch widths around the word boundary
(B = 1 / 32 / 48), both combine ops, and the engine/distributed layers
that select it.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.compat import make_mesh
from repro.core import bfs_oracle, partition_graph
from repro.core.bfs_distributed import DistConfig, DistributedBFS
from repro.core.bfs_local import build_local_graph
from repro.core.scheduler import SchedulerConfig
from repro.core.vertex_program import (MultiSourceBFSRunner, SSSPRunner,
                                       msbfs_reference)
from repro.graph import csr_from_edges, transpose_csr, uniform_edges
from repro.kernels import ops, ref

TILE = 16          # forced tile size for the differential cases
BLOCK = 32         # forced edge-chunk size (small => many chunks per tile)


def _planes(n, nw, seed):
    rng = np.random.default_rng(seed)
    frontier = rng.integers(0, 2**32, (n, nw), dtype=np.uint32)
    seen = rng.integers(0, 2**32, (n, nw), dtype=np.uint32)
    return frontier, seen


def _assert_tiled_matches(frontier, seen, src, tgt, valid, op="or",
                          tile_rows=TILE, block_edges=BLOCK):
    """Tiled == whole-VMEM == jnp oracle, bit for bit (new/seen/count)."""
    n = frontier.shape[0]
    args = (jnp.asarray(frontier), jnp.asarray(seen), jnp.asarray(src),
            jnp.asarray(tgt), jnp.asarray(valid))
    got_t = ops.msbfs_propagate(*args, block_edges=block_edges,
                                interpret=True, op=op, tile_rows=tile_rows)
    got_w = ops.msbfs_propagate(*args, block_edges=block_edges,
                                interpret=True, op=op, tile_rows=0)
    ok = (valid & (src >= 0) & (src < n) & (tgt >= 0) & (tgt < n))
    msg = np.where(ok[:, None], frontier[np.clip(src, 0, n - 1)], 0)
    want = ref.msbfs_propagate_msgs_ref(
        jnp.asarray(seen), jnp.asarray(msg), jnp.asarray(tgt),
        jnp.asarray(ok), op=op)
    for g, w, o, name in zip(got_t, got_w, want, ("new", "seen", "cnt")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"tiled vs whole: {name}")
        np.testing.assert_array_equal(
            np.asarray(g).reshape(-1), np.asarray(o).reshape(-1),
            err_msg=f"tiled vs oracle: {name}")


@pytest.mark.parametrize("batch", [1, 32, 48])
@pytest.mark.parametrize("op", ["or", "max"])
def test_tiled_differential_random(batch, op):
    """Random edges at B = 1 / 32 / 48 (nw = 1, 1, 2), both combine ops,
    invalid and out-of-range slots mixed in."""
    nw = (batch + 31) // 32
    n, m = 100, 700
    frontier, seen = _planes(n, nw, seed=batch * 7 + len(op))
    rng = np.random.default_rng(batch * 13 + len(op))
    src = rng.integers(-2, n + 3, m).astype(np.int32)
    tgt = rng.integers(-2, n + 3, m).astype(np.int32)
    valid = rng.random(m) < 0.85
    _assert_tiled_matches(frontier, seen, src, tgt, valid, op=op)


def test_tiled_tile_boundary_straddling():
    """Every edge targets a row adjacent to a tile boundary: the kernel's
    global->tile-local index arithmetic is exercised at both edges of
    every tile."""
    n, nw = 8 * TILE, 2
    frontier, seen = _planes(n, nw, seed=3)
    bounds = np.arange(TILE, n, TILE, dtype=np.int32)
    tgt = np.concatenate([bounds - 1, bounds, bounds + 1,
                          np.asarray([0, n - 1], np.int32)])
    tgt = np.tile(tgt, 5)
    rng = np.random.default_rng(4)
    src = rng.integers(0, n, tgt.size).astype(np.int32)
    valid = np.ones(tgt.size, bool)
    _assert_tiled_matches(frontier, seen, src, tgt, valid)


@pytest.mark.parametrize("op", ["or", "max"])
def test_tiled_hub_source_spans_tiles(op):
    """One hub vertex's out-list spans >= 3 row tiles (its frontier word
    is gathered once per edge but scattered into many tiles)."""
    n, nw = 6 * TILE, 1
    frontier, seen = _planes(n, nw, seed=11)
    hub = 7
    tgt = np.arange(0, 5 * TILE, 1, dtype=np.int32)       # tiles 0..4
    src = np.full(tgt.size, hub, np.int32)
    valid = np.ones(tgt.size, bool)
    _assert_tiled_matches(frontier, seen, src, tgt, valid, op=op)


def test_tiled_hub_target_overflows_chunk():
    """Degree-aware budget tiling: one hub TARGET draws far more edges
    than one ``block_edges`` chunk holds, so its tile must be allocated
    multiple chunks while other tiles stay small."""
    n, nw = 5 * TILE, 1
    frontier, seen = _planes(n, nw, seed=17)
    m = 6 * BLOCK + 11                       # >6 chunks aimed at one row
    rng = np.random.default_rng(18)
    src = rng.integers(0, n, m).astype(np.int32)
    tgt = np.full(m, 2 * TILE + 3, np.int32)  # all into tile 2
    # plus a sprinkle elsewhere so other tiles are non-empty
    tgt[::13] = rng.integers(0, n, tgt[::13].size)
    valid = np.ones(m, bool)
    _assert_tiled_matches(frontier, seen, src, tgt, valid)


def test_tiled_empty_tiles_still_commit_p3():
    """Tiles receiving no edges must still run P3 (new=0 against their
    seen) — their rows must come back exact, not stale."""
    n, nw = 7 * TILE, 1
    frontier, seen = _planes(n, nw, seed=23)
    tgt = np.full(40, 3, np.int32)           # all edges into tile 0
    src = np.arange(40, dtype=np.int32)
    valid = np.ones(40, bool)
    _assert_tiled_matches(frontier, seen, src, tgt, valid)


def test_tiled_all_edges_invalid():
    n, nw = 3 * TILE, 1
    frontier, seen = _planes(n, nw, seed=29)
    m = 50
    src = np.arange(m, dtype=np.int32)
    tgt = np.arange(m, dtype=np.int32) % n
    valid = np.zeros(m, bool)
    _assert_tiled_matches(frontier, seen, src, tgt, valid)


def test_tiled_rows_not_tile_multiple():
    """n not divisible by tile_rows: the pad rows (seen = all-ones) must
    never surface as discoveries or counts."""
    for n in (TILE + 1, 3 * TILE - 1, 37):
        frontier, seen = _planes(n, 1, seed=n)
        rng = np.random.default_rng(n + 1)
        m = 200
        src = rng.integers(0, n, m).astype(np.int32)
        tgt = rng.integers(0, n, m).astype(np.int32)
        _assert_tiled_matches(frontier, seen, src, tgt, np.ones(m, bool))


@pytest.mark.parametrize("op", ["or", "max"])
def test_sequential_loop_body_matches_vectorized(op):
    """The compiled-TPU per-edge RMW loop and the interpret-mode
    vectorized chunk scatter are the same function: force each body of
    both kernels under the interpreter and compare bit for bit."""
    from repro.kernels.msbfs_propagate import (msbfs_propagate_planes,
                                               msbfs_propagate_planes_tiled)
    n, nw, m = 4 * TILE, 2, 8 * BLOCK
    frontier, seen = _planes(n + 1, nw, seed=5)
    frontier[n] = 0
    seen[n] = np.uint32(0xFFFFFFFF)       # trash-row form of the whole kernel
    rng = np.random.default_rng(11)
    src = jnp.asarray(rng.integers(0, n + 1, m).astype(np.int32))
    tgt = jnp.asarray(rng.integers(0, n + 1, m).astype(np.int32))
    loop, vec = (msbfs_propagate_planes(
        jnp.asarray(frontier), jnp.asarray(seen), src, tgt,
        block_edges=BLOCK, interpret=True, op=op, vector_scatter=v)
        for v in (False, True))
    for a, b, name in zip(loop, vec, ("new", "seen", "cnt")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"whole kernel: {name}")
    fr, sn = _planes(n, nw, seed=6)
    half = m // 2
    msg = jnp.asarray(fr)[src[:half] % n]
    tg = tgt[:half] % n
    sm, st, ct = ops._bucket_edges_by_tile(
        msg, tg, jnp.ones(half, bool), n // TILE, TILE, BLOCK)
    loop, vec = (msbfs_propagate_planes_tiled(
        jnp.asarray(sn), sm, st, ct, tile_rows=TILE, block_edges=BLOCK,
        interpret=True, op=op, vector_scatter=v)
        for v in (False, True))
    for a, b, name in zip(loop, vec, ("new", "seen", "cnt")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"tiled kernel: {name}")


def test_tiled_noninterpret_parity():
    """Non-interpret arm of the tiled differential (TPU-only compile)."""
    if jax.default_backend() != "tpu":
        pytest.skip("non-interpret Pallas path needs a TPU backend")
    n, nw = 8 * TILE, 1
    frontier, seen = _planes(n, nw, seed=31)
    rng = np.random.default_rng(32)
    m = 500
    src = rng.integers(0, n, m).astype(np.int32)
    tgt = rng.integers(0, n, m).astype(np.int32)
    args = (jnp.asarray(frontier), jnp.asarray(seen), jnp.asarray(src),
            jnp.asarray(tgt), jnp.ones(m, bool))
    got = ops.msbfs_propagate(*args, block_edges=128, interpret=False,
                              tile_rows=TILE)
    want = ops.msbfs_propagate(*args, block_edges=128, interpret=True,
                               tile_rows=TILE)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# bucketing invariants (the host/jnp side of the tiled contract)
# ---------------------------------------------------------------------------

def test_bucket_edges_by_tile_invariants():
    n, nw, m, tr, c = 100, 2, 333, 16, 32
    t = -(-n // tr)
    rng = np.random.default_rng(5)
    msg = rng.integers(0, 2**32, (m, nw), dtype=np.uint32)
    tgt = rng.integers(0, n, m).astype(np.int32)
    ok = rng.random(m) < 0.8
    msg[~ok] = 0
    sm, st, ct = (np.asarray(x) for x in ops._bucket_edges_by_tile(
        jnp.asarray(msg), jnp.asarray(tgt), jnp.asarray(ok), t, tr, c))
    nc = -(-m // c) + t
    assert ct.shape == (nc,) and sm.shape == (nc * c, nw)
    # nondecreasing chunk->tile map covering every tile (the kernel's
    # accumulator-persistence + P3-once-per-tile invariant)
    assert (np.diff(ct) >= 0).all()
    np.testing.assert_array_equal(np.unique(ct), np.arange(t))
    # every streamed slot's target lies inside its chunk's tile
    slot_tile = np.repeat(ct, c)
    assert ((st >= slot_tile * tr) & (st < (slot_tile + 1) * tr)).all()
    # the multiset of valid (tgt, msg) pairs survives exactly; pad slots
    # carry msg = 0 (the combine identity)
    want = sorted((int(tgt[e]), msg[e].tobytes()) for e in range(m) if ok[e])
    got = sorted((int(st[i]), sm[i].tobytes()) for i in range(nc * c)
                 if sm[i].any())
    assert got == want


def test_propagate_plan_selection():
    # rmat16 @ B=32 stays whole-VMEM under the default ~2 MiB budget;
    # rmat20 and wide batches tile
    assert not ops.propagate_plan(1 << 16, 1)["tiled"]
    assert ops.propagate_plan(1 << 20, 1)["tiled"]
    assert ops.propagate_plan(1 << 16, 4)["tiled"]
    # explicit budget override + forced modes
    p = ops.propagate_plan(1000, 1, vmem_bytes=1024)
    assert p["tiled"] and p["tile_rows"] >= 8
    assert p["num_tiles"] == -(-1000 // p["tile_rows"])
    assert not ops.propagate_plan(1 << 20, 1, tile_rows=0)["tiled"]
    assert ops.propagate_plan(100, 1, tile_rows=16)["num_tiles"] == 7
    with pytest.raises(ValueError):
        ops.propagate_plan(100, 1, tile_rows=-3)


# ---------------------------------------------------------------------------
# msgs-form entry (the distributed pull's contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["or", "max"])
def test_msbfs_propagate_msgs_vs_ref(op):
    n, nw, m = 90, 2, 400
    rng = np.random.default_rng(41)
    seen = rng.integers(0, 2**32, (n, nw), dtype=np.uint32)
    msg = rng.integers(0, 2**32, (m, nw), dtype=np.uint32)
    tgt = rng.integers(-3, n + 3, m).astype(np.int32)
    valid = rng.random(m) < 0.8
    got = ops.msbfs_propagate_msgs(
        jnp.asarray(seen), jnp.asarray(msg), jnp.asarray(tgt),
        jnp.asarray(valid), tile_rows=TILE, block_edges=BLOCK,
        interpret=True, op=op)
    want = ref.msbfs_propagate_msgs_ref(
        jnp.asarray(seen), jnp.asarray(msg), jnp.asarray(tgt),
        jnp.asarray(valid), op=op)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g).reshape(-1),
                                      np.asarray(w).reshape(-1))


# ---------------------------------------------------------------------------
# engine + distributed layers select / survive the tiled kernel
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph():
    src, dst = uniform_edges(300, 1500, seed=9)
    csr = csr_from_edges(src, dst, 300)
    return csr, build_local_graph(csr, transpose_csr(csr))


@pytest.mark.parametrize("batch", [1, 32, 48])
def test_engine_tiled_matches_reference(graph, batch):
    _, g = graph
    roots = np.random.default_rng(batch).choice(300, batch,
                                                replace=False).astype(np.int32)
    want = np.asarray(msbfs_reference(g, roots))
    got = MultiSourceBFSRunner(g, use_pallas=True,
                               tile_rows=64).run(roots).levels
    np.testing.assert_array_equal(got, want)
    # whole-VMEM arm of the same differential
    got_w = MultiSourceBFSRunner(g, use_pallas=True,
                                 tile_rows=0).run(roots).levels
    np.testing.assert_array_equal(got_w, want)


def test_engine_tiled_pull_only(graph):
    """Force the budgeted Pallas pull so the tiled kernel runs in the
    pull direction too (child/parent swapped relative to push)."""
    _, g = graph
    roots = np.arange(8, dtype=np.int32)
    want = np.asarray(msbfs_reference(g, roots))
    r = MultiSourceBFSRunner(g, SchedulerConfig(policy="pull"),
                             use_pallas=True, tile_rows=32)
    np.testing.assert_array_equal(r.run(roots).levels, want)


def test_sssp_rides_tiled_propagate(graph):
    _, g = graph
    roots = np.arange(5, dtype=np.int32)
    want = SSSPRunner(g).run(roots).levels
    got = SSSPRunner(g, use_pallas=True, tile_rows=32).run(roots).levels
    np.testing.assert_array_equal(got, want)


def test_distributed_pull_uses_tiled_kernel(graph):
    """DistConfig(use_pallas=True): the batched pull runs the msgs-form
    tiled kernel with tile_rows = verts_per_shard (one tile per PE) and
    must match the per-root oracle exactly."""
    csr, _ = graph
    pg = partition_graph(csr, transpose_csr(csr), 4)
    mesh = make_mesh((1,), ("data",))
    roots = np.asarray([0, 3, 11, 200], np.int64)
    cfg = DistConfig(use_pallas=True,
                     scheduler=SchedulerConfig(policy="pull"))
    got = DistributedBFS(pg, mesh, cfg=cfg).run_batch(roots)
    jnp_cfg = DistConfig(scheduler=SchedulerConfig(policy="pull"))
    want = DistributedBFS(pg, mesh, cfg=jnp_cfg).run_batch(roots)
    np.testing.assert_array_equal(got, want)
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(got[i], bfs_oracle(csr, int(r)))


@pytest.mark.slow
def test_tiled_auto_selection_medium_graph():
    """End-to-end auto-select on a graph big enough that the default plan
    tiles (via a squeezed VMEM budget env knob is NOT used — instead the
    tile_rows=None auto rule is exercised directly through plan + a
    forced-tile engine run on a mid-size rmat graph)."""
    from repro.graph.generators import rmat_edges
    from repro.graph.csr import csr_from_edges as _cfe
    n = 1 << 13
    src, dst = rmat_edges(13, 8, seed=1)
    csr = _cfe(src, dst, n)
    g = build_local_graph(csr, transpose_csr(csr))
    roots = np.random.default_rng(0).choice(
        np.flatnonzero(np.diff(csr.indptr) > 0), 32,
        replace=False).astype(np.int32)
    want = np.asarray(msbfs_reference(g, roots))
    got = MultiSourceBFSRunner(g, use_pallas=True,
                               tile_rows=1024).run(roots).levels
    np.testing.assert_array_equal(got, want)
