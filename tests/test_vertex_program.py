"""Differential tests for the generic vertex-program engine.

The tentpole contract: the (init, apply/commit, combine, done) bundle
drives ONE shared packed-plane pipeline, and every instantiation —
BFS (covered in test_msbfs_differential), CC and SSSP here — must agree
bit-for-bit with an independent dense numpy oracle (union-find component
labels for CC, Bellman–Ford relaxation for SSSP) at batch widths that
exercise partial plane words (1, 32, 48), with and without the Pallas
propagate kernel, on graphs with isolated vertices and self-loops.

Also pinned: the inherited one-sync-per-level protocol
(``host_transfers == iterations + 2``) and shared root validation, the
``vp_reference`` dense loop, the serve/dynbatch integration of the
``--algo`` paths, and the program-parameterized distributed engine.
"""
import json

import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import (CC, SSSP, ConnectedComponentsRunner,
                        MultiSourceBFSRunner, SSSPRunner, VertexProgram,
                        bfs_oracle, build_local_graph, component_labels,
                        get_program, partition_graph, vp_reference)
from repro.core.bfs_distributed import DistributedBFS
from repro.graph import csr_from_edges, symmetrize_csr, transpose_csr

N = 128
INF = 1 << 30


def _awkward_graph(n: int, m: int, seed: int):
    """Random digraph with guaranteed isolated vertices and self-loops
    (same construction as the MS-BFS differential sweep)."""
    rng = np.random.default_rng(seed)
    hi = (3 * n) // 4
    src = rng.integers(0, hi, m)
    dst = rng.integers(0, hi, m)
    loops = np.arange(0, hi, 16)
    src = np.concatenate([src, loops])
    dst = np.concatenate([dst, loops])
    csr = csr_from_edges(src, dst, n)
    assert (np.diff(csr.indptr)[hi:] == 0).all()      # isolates exist
    return csr


def _roots(n: int, batch: int, seed: int) -> np.ndarray:
    """Roots including an isolated vertex and a self-loop vertex."""
    rng = np.random.default_rng(seed)
    roots = rng.choice(n, batch, replace=False)
    if batch >= 2:
        roots[0] = n - 1        # isolated (edges confined to [0, 3n/4))
        roots[1] = 16           # self-loop vertex
    return roots.astype(np.int32)


# ---------------------------------------------------------------------------
# independent numpy oracles
# ---------------------------------------------------------------------------

def _bellman_ford_oracle(csr, root: int) -> np.ndarray:
    """Dense unit-weight Bellman–Ford: relax every edge until fixpoint."""
    n = csr.indptr.size - 1
    src = np.repeat(np.arange(n), np.diff(csr.indptr))
    dst = np.asarray(csr.indices)
    dist = np.full(n, INF, np.int64)
    dist[root] = 0
    for _ in range(n):
        nd = dist.copy()
        np.minimum.at(nd, dst, np.minimum(dist[src] + 1, INF))
        if (nd == dist).all():
            break
        dist = nd
    return dist


def _cc_oracle_labels(csr, seeds: np.ndarray) -> np.ndarray:
    """Union-find over the undirected edge set; label[v] = min seed id in
    v's component, -1 when no seed lands in it."""
    n = csr.indptr.size - 1
    src = np.repeat(np.arange(n), np.diff(csr.indptr))
    dst = np.asarray(csr.indices)
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(src.tolist(), dst.tolist()):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
    comp = np.asarray([find(v) for v in range(n)])
    labels = np.full(n, -1, np.int64)
    for s in sorted((int(s) for s in seeds), reverse=True):
        labels[comp == comp[s]] = s
    return labels


# ---------------------------------------------------------------------------
# CC differential: runner vs union-find oracle vs per-seed BFS oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp-p3", "pallas-p3"])
@pytest.mark.parametrize("batch", [1, 32, 48])
def test_cc_runner_vs_oracles(batch, use_pallas):
    csr = _awkward_graph(N, 512, seed=300 + batch)
    seeds = _roots(N, batch, seed=batch + 5)
    res = ConnectedComponentsRunner.from_csr(
        csr, use_pallas=use_pallas).run(seeds)
    assert res.algo == "cc" and res.levels.shape == (batch, N)
    np.testing.assert_array_equal(res.labels, _cc_oracle_labels(csr, seeds))
    # per-seed reach levels are BFS levels on the symmetrized graph
    sym = symmetrize_csr(csr)
    for i, s in enumerate(seeds):
        np.testing.assert_array_equal(res.levels[i].astype(np.int64),
                                      bfs_oracle(sym, int(s)))


def test_cc_labels_uniform_and_component_count():
    csr = _awkward_graph(N, 512, seed=17)
    seeds = _roots(N, 32, seed=2)
    runner = ConnectedComponentsRunner.from_csr(csr)
    res = runner.run(seeds)
    # all seeds in one component agree on the min-seed label; every seed
    # labels at least itself
    for i, s in enumerate(seeds):
        assert res.labels[s] >= 0 and res.labels[s] <= s
    n_components = int(np.unique(res.labels[res.labels >= 0]).size)
    assert runner.last_stats["components"] == n_components >= 1
    # an isolated seed is its own component
    assert res.labels[N - 1] == N - 1


def test_component_labels_min_seed_semantics():
    levels = np.asarray([[0, 1, INF, INF],      # seed 3 reaches {0, 1}
                         [1, 0, INF, INF],      # seed 1 reaches {0, 1}
                         [INF, INF, 0, INF]])   # seed 2 reaches {2}
    labels = component_labels(levels, np.asarray([3, 1, 2]))
    np.testing.assert_array_equal(labels, [1, 1, 2, -1])


# ---------------------------------------------------------------------------
# SSSP differential: runner vs dense Bellman–Ford, and vs BFS (unit
# weights make them coincide — on the SAME directed graph)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp-p3", "pallas-p3"])
@pytest.mark.parametrize("batch", [1, 32, 48])
def test_sssp_runner_vs_bellman_ford(batch, use_pallas):
    csr = _awkward_graph(N, 512, seed=400 + batch)
    g = build_local_graph(csr, transpose_csr(csr))
    roots = _roots(N, batch, seed=3 * batch + 2)
    res = SSSPRunner(g, use_pallas=use_pallas).run(roots)
    assert res.algo == "sssp"
    assert res.distances is res.levels          # SSSP alias
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(res.distances[i].astype(np.int64),
                                      _bellman_ford_oracle(csr, int(r)))


def test_sssp_equals_bfs_on_unit_weights():
    csr = _awkward_graph(N, 512, seed=8)
    g = build_local_graph(csr, transpose_csr(csr))
    roots = _roots(N, 33, seed=4)               # crosses a plane word
    sssp = SSSPRunner(g).run(roots)
    bfs = MultiSourceBFSRunner(g).run(roots)
    np.testing.assert_array_equal(sssp.distances, bfs.levels)


# ---------------------------------------------------------------------------
# inherited engine contracts: one-sync-per-level + shared root validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda g, csr: ConnectedComponentsRunner.from_csr(csr),
    lambda g, csr: SSSPRunner(g),
], ids=["cc", "sssp"])
def test_one_host_transfer_per_level_inherited(make):
    csr = _awkward_graph(N, 512, seed=9)
    g = build_local_graph(csr, transpose_csr(csr))
    res = make(g, csr).run(_roots(N, 32, seed=3))
    assert res.iterations > 1
    assert res.host_transfers == res.iterations + 2


@pytest.mark.parametrize("make", [
    lambda g, csr: ConnectedComponentsRunner.from_csr(csr),
    lambda g, csr: SSSPRunner(g),
], ids=["cc", "sssp"])
def test_root_validation_inherited(make):
    csr = _awkward_graph(N, 256, seed=1)
    g = build_local_graph(csr, transpose_csr(csr))
    runner = make(g, csr)
    with pytest.raises(ValueError):
        runner.run(np.asarray([0, N], np.int32))
    with pytest.raises(ValueError):
        runner.run(np.asarray([2 ** 32 + 5], np.int64))   # must not wrap
    with pytest.raises(ValueError, match="integers"):
        runner.run(np.asarray([5.7]))                     # must not truncate


# ---------------------------------------------------------------------------
# vp_reference: the dense jit loop must agree per program
# ---------------------------------------------------------------------------

def test_vp_reference_parity():
    csr = _awkward_graph(N, 512, seed=23)
    roots = _roots(N, 31, seed=6)
    g = build_local_graph(csr, transpose_csr(csr))
    np.testing.assert_array_equal(np.asarray(vp_reference(g, roots, SSSP)),
                                  SSSPRunner(g).run(roots).distances)
    sym = symmetrize_csr(csr)
    g_sym = build_local_graph(sym, transpose_csr(sym))
    np.testing.assert_array_equal(
        np.asarray(vp_reference(g_sym, roots, CC)),
        ConnectedComponentsRunner(g_sym).run(roots).levels)


def test_get_program_registry():
    assert get_program("cc") is CC and get_program("sssp") is SSSP
    assert get_program("bfs").name == "bfs"
    with pytest.raises(ValueError, match="unknown vertex program"):
        get_program("pagerank")


# ---------------------------------------------------------------------------
# serving integration: build_engine / bfs_batch / dynbatch over --algo
# ---------------------------------------------------------------------------

def test_build_engine_serves_cc_and_sssp_locally():
    from repro.graph import get_dataset
    from repro.launch.serve import bfs_batch, build_engine
    csr = get_dataset("tiny-16-4").csr
    roots = [0, 5, 9]

    engine, deg = build_engine("tiny-16-4", algo="sssp", distributed=False)
    out = bfs_batch(roots, engine=engine, out_deg=deg)
    assert out["algo"] == "sssp" and out["batch"] == 3
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(
            np.asarray(out["levels"][i], np.int64),
            _bellman_ford_oracle(csr, r))

    engine, deg = build_engine("tiny-16-4", algo="cc", distributed=False)
    out = bfs_batch(roots, engine=engine, out_deg=deg)
    assert out["algo"] == "cc" and out["components"] >= 1
    sym = symmetrize_csr(csr)
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(
            np.asarray(out["levels"][i], np.int64), bfs_oracle(sym, r))
    # stats (levels popped) must be JSON-serializable for the serve CLI
    out.pop("levels")
    json.dumps(out)


def test_serve_bfs_async_algo_paths_return_json_stats():
    from repro.launch.serve import serve_bfs_async
    for algo in ("cc", "sssp"):
        out = serve_bfs_async("tiny-16-4", requests=6, window=0.01,
                              max_batch=8, algo=algo)
        assert out["algo"] == algo and out["requests"] == 6
        assert out["waves"] >= 1
        json.dumps(out)


def test_dynbatcher_discovers_out_deg_via_protocol():
    """Satellite: no ``out_deg=`` kwarg and no ``.g`` sniffing — the
    batcher reads the engine protocol's ``out_deg`` property, so TEPS
    stats survive for CC/SSSP engines too."""
    from repro.launch.dynbatch import DynamicBatcher
    from repro.launch.serve import build_engine
    engine, deg = build_engine("tiny-16-4", algo="cc", distributed=False)
    b = DynamicBatcher(engine, window=10.0, clock=lambda: 0.0)
    np.testing.assert_array_equal(b.out_deg, deg)
    for r in (0, 3, 7):
        b.submit(r, block=False)
    waves = b.flush()
    assert len(waves) == 1 and waves[0].traversed_edges > 0
    assert "aggregate_teps" in b.stats()
    b.close()


# ---------------------------------------------------------------------------
# distributed engine carrying a program
# ---------------------------------------------------------------------------

def _dist_engine(program, seed: int = 3, symmetric: bool = False):
    rng = np.random.default_rng(seed)
    src, dst = rng.integers(0, 64, 256), rng.integers(0, 64, 256)
    csr = csr_from_edges(src, dst, 64)
    if symmetric:
        csr = symmetrize_csr(csr)
    pg = partition_graph(csr, transpose_csr(csr), 4)
    mesh = make_mesh((1,), ("data",))
    return csr, DistributedBFS(pg, mesh, program=program)


def test_distributed_sssp_vs_bellman_ford():
    csr, eng = _dist_engine(SSSP)
    roots = np.asarray([0, 2, 31, 63])
    dists = eng.run_batch(roots)
    assert eng.last_stats["algo"] == "sssp"
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(dists[i],
                                      _bellman_ford_oracle(csr, int(r)))


def test_distributed_cc_vs_bfs_oracle():
    csr, eng = _dist_engine(CC, symmetric=True)
    seeds = np.asarray([0, 5, 40, 63])
    levels = eng.run_batch(seeds)
    for i, s in enumerate(seeds):
        np.testing.assert_array_equal(levels[i], bfs_oracle(csr, int(s)))
    labels = component_labels(levels, seeds)
    np.testing.assert_array_equal(labels, _cc_oracle_labels(csr, seeds))


def test_distributed_rejects_non_or_combine():
    """The distributed crossbar is an OR-reduce-scatter; a payload-plane
    combine must fail loudly rather than silently OR the planes."""
    csr, eng = _dist_engine(SSSP)
    payload = VertexProgram(name="payload-max", combine="max")
    with pytest.raises(NotImplementedError, match="OR-reduce-scatter"):
        eng.run_program_batch(payload, np.asarray([0, 1]))


# ---------------------------------------------------------------------------
# sparse (budgeted) pull: unit differential vs the dense scan, and the
# end-to-end driver crossover
# ---------------------------------------------------------------------------

def test_sparse_pull_matches_dense_scan_unit():
    """_propagate_pull_sparse must agree with the dense CSC scan on an
    arbitrary mid-traversal plane state (multi-word, with pad planes),
    and report the exact m_u edge total for the overflow contract."""
    from repro.core import bitmap
    from repro.core.vertex_program import (_propagate_pull_scan,
                                           _propagate_pull_sparse)

    csr = _awkward_graph(N, 512, seed=77)
    g = build_local_graph(csr, transpose_csr(csr))
    nb = 33                                 # two plane words, one partial
    nw = bitmap.num_words(nb)
    pmask = np.asarray(bitmap.plane_mask(nb))
    rng = np.random.default_rng(9)
    frontier = (rng.integers(0, 1 << 32, (g.n_pad, nw), dtype=np.uint32)
                & pmask)
    seen = (frontier
            | (rng.integers(0, 1 << 32, (g.n_pad, nw), dtype=np.uint32)
               & pmask))
    frontier[g.n:] = 0                      # pad vertices carry no state
    seen[g.n:] = pmask                      # pad vertices: all planes seen

    dense_new = np.asarray(_propagate_pull_scan(g, frontier)) & ~seen
    # exact unseen-edge total: sum of in-degrees over any-plane-unseen
    in_deg = np.diff(np.asarray(g.in_indptr))[: g.n_pad]
    un_any = ((~seen & pmask) != 0).any(axis=1)
    m_u = int(in_deg[un_any].sum())

    new, seen2, total = _propagate_pull_sparse(
        g, frontier, seen, nb, max(1 << (m_u - 1).bit_length(), 64))
    assert int(total) == m_u
    np.testing.assert_array_equal(np.asarray(new), dense_new)
    np.testing.assert_array_equal(np.asarray(seen2), seen | dense_new)

    # truncated budget: total still reports m_u so the driver retries
    if m_u > 4:
        _, _, short = _propagate_pull_sparse(g, frontier, seen, nb,
                                             m_u // 2)
        assert int(short) == m_u


@pytest.mark.parametrize("batch", [1, 33])
def test_sparse_pull_runner_matches_dense_runner(batch):
    """End-to-end: a sparse_pull=True runner must produce identical
    levels to the dense runner and the per-root oracle, with the sparse
    path actually taken on tail pull levels (spied via _pull_budget) and
    the one-fetch-per-level transfer invariant intact."""
    from repro.core.scheduler import SchedulerConfig

    # big enough that the crossover rule (pb * 8 <= E) can fire
    src = np.random.default_rng(4).integers(0, 4096, 40000)
    dst = np.random.default_rng(5).integers(0, 4096, 40000)
    csr = csr_from_edges(src, dst, 4096)
    g = build_local_graph(csr, transpose_csr(csr))
    roots = np.random.default_rng(6).choice(4096, batch, replace=False)

    dense = MultiSourceBFSRunner(g, sched=SchedulerConfig(policy="pull"))
    sparse = MultiSourceBFSRunner(g, sched=SchedulerConfig(policy="pull"),
                                  sparse_pull=True)
    budgets = []
    orig = sparse._pull_budget

    def spy(m_u):
        pb = orig(m_u)
        budgets.append(pb)
        return pb

    sparse._pull_budget = spy
    want = dense.run(roots).levels
    res = sparse.run(roots)
    np.testing.assert_array_equal(res.levels, want)
    np.testing.assert_array_equal(
        np.asarray(res.levels[0], np.int64)[: 4096],
        bfs_oracle(csr, int(roots[0])))
    assert any(pb > 0 for pb in budgets)    # sparse path actually ran
    assert any(pb == 0 for pb in budgets)   # full-stream levels stay dense
    assert sparse.last_stats["host_transfers"] == res.iterations + 2
    # device-side per-plane traversed counts agree with the host recount
    from repro.core import count_traversed_edges
    deg = np.diff(csr.indptr)
    assert sum(sparse.last_stats["traversed_per_plane"]) == \
        count_traversed_edges(deg, res.levels)
