"""Batched multi-source BFS (MS-BFS) vs a per-root oracle loop.

Covers the batched bit-plane helpers, the batched P3 kernel, the local
``MultiSourceBFSRunner`` (random + RMAT graphs, all scheduler policies),
and the distributed ``run_batch`` path.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.compat import make_mesh
from repro.core import (MultiSourceBFSRunner, SchedulerConfig, bfs_oracle,
                        bitmap, build_local_graph, msbfs_reference,
                        partition_graph)
from repro.core.bfs_distributed import DistConfig, DistributedBFS
from repro.graph import (csr_from_edges, get_dataset, rmat_edges,
                         transpose_csr, uniform_edges)
from repro.testing import given, settings, strategies as st


def _graph_from_edges(src, dst, n):
    csr = csr_from_edges(src, dst, n)
    return csr, build_local_graph(csr, transpose_csr(csr))


def _assert_matches_oracle(levels, csr, roots):
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(levels[i].astype(np.int64),
                                      bfs_oracle(csr, int(r)))


# ---------------------------------------------------------------------------
# bit-plane helpers + batched P3 kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb", [1, 31, 32, 33, 64, 100])
def test_pack_unpack_rows_roundtrip(nb):
    rng = np.random.default_rng(nb)
    mask = jnp.asarray(rng.random((57, nb)) < 0.3)
    w = bitmap.pack_rows(mask)
    assert w.shape == (57, bitmap.num_words(nb))
    np.testing.assert_array_equal(
        np.asarray(bitmap.unpack_rows(w, nb)), np.asarray(mask))
    np.testing.assert_array_equal(
        np.asarray(bitmap.any_rows(w)), np.asarray(mask).any(1))
    np.testing.assert_array_equal(
        np.asarray(bitmap.popcount_rows(w)), np.asarray(mask).sum(1))


def test_plane_mask_covers_exactly_num_bits():
    for nb in (1, 31, 32, 33, 64):
        m = bitmap.plane_mask(nb)
        np.testing.assert_array_equal(
            np.asarray(bitmap.unpack(m)), np.arange(len(m) * 32) < nb)


def test_bitmap_update_batch_matches_ref():
    from repro.kernels.bitmap_update import bitmap_update_batch
    from repro.kernels.ref import bitmap_update_batch_ref
    rng = np.random.default_rng(7)
    cand = jnp.asarray(rng.integers(0, 2**32, (3, 32, 128), dtype=np.uint32))
    vis = jnp.asarray(rng.integers(0, 2**32, (3, 32, 128), dtype=np.uint32))
    got = bitmap_update_batch(cand, vis, block_rows=16)
    want = bitmap_update_batch_ref(cand, vis)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_fused_frontier_update_batch_odd_widths():
    from repro.kernels import ops
    rng = np.random.default_rng(11)
    for w in (1, 100, 128, 1000):
        c = jnp.asarray(rng.integers(0, 2**32, (5, w), dtype=np.uint32))
        v = jnp.asarray(rng.integers(0, 2**32, (5, w), dtype=np.uint32))
        nf, vo, cnt = ops.fused_frontier_update_batch(c, v)
        np.testing.assert_array_equal(np.asarray(nf), np.asarray(c & ~v))
        np.testing.assert_array_equal(np.asarray(vo),
                                      np.asarray(v | (c & ~v)))
        np.testing.assert_array_equal(
            np.asarray(cnt), np.asarray(bitmap.popcount_rows(c & ~v)))


@pytest.mark.parametrize("batch", [1, 33])
def test_count_traversed_edges_matches_loop(batch):
    """The vectorized masked-matvec must pin the original per-row loop."""
    from repro.core import count_traversed_edges
    from repro.core.bfs_local import INF
    rng = np.random.default_rng(batch)
    n = 200
    out_deg = rng.integers(0, 50, n)
    levels = np.where(rng.random((batch, n)) < 0.4,
                      rng.integers(0, 9, (batch, n)), int(INF))
    want = int(sum(out_deg[levels[i] < int(INF)].sum()
                   for i in range(batch)))
    assert count_traversed_edges(out_deg, levels) == want
    if batch == 1:   # 1-D input (single-source BFSResult.level) still works
        assert count_traversed_edges(out_deg, levels[0]) == want


# ---------------------------------------------------------------------------
# local MS-BFS engine
# ---------------------------------------------------------------------------

def test_msbfs_reference_matches_oracle_loop():
    src, dst = uniform_edges(256, 1024, seed=5)
    csr, g = _graph_from_edges(src, dst, 256)
    roots = np.arange(0, 40, 5, dtype=np.int32)
    _assert_matches_oracle(np.asarray(msbfs_reference(g, roots)), csr, roots)


def test_runner_matches_oracle_random_graph_32_roots():
    """Acceptance: batch of >=32 roots == per-root oracle (random graph)."""
    src, dst = uniform_edges(512, 4096, seed=2)
    csr, g = _graph_from_edges(src, dst, 512)
    roots = np.random.default_rng(0).choice(512, 34, replace=False)
    res = MultiSourceBFSRunner(g).run(roots)
    _assert_matches_oracle(res.levels, csr, roots)
    assert res.batch == 34 and res.traversed_edges > 0


def test_runner_matches_oracle_rmat_32_roots():
    """Acceptance: batch of >=32 roots == per-root oracle (RMAT graph)."""
    ds = get_dataset("small-12-8")
    roots = np.random.default_rng(1).choice(ds.csr.num_vertices, 32,
                                            replace=False)
    res = MultiSourceBFSRunner(build_local_graph(ds.csr, ds.csc)).run(roots)
    _assert_matches_oracle(res.levels, ds.csr, roots)
    # sanity: MS-BFS inspected far fewer edges than 32 separate runs would
    assert res.edges_inspected < 32 * ds.csr.num_edges


@pytest.mark.parametrize("policy", ["push", "pull", "beamer", "paper"])
def test_runner_all_policies(policy):
    src, dst = rmat_edges(8, 8, seed=4)
    csr, g = _graph_from_edges(src, dst, 256)
    roots = np.asarray([0, 3, 17, 101, 255], np.int32)
    res = MultiSourceBFSRunner(g, SchedulerConfig(policy=policy)).run(roots)
    _assert_matches_oracle(res.levels, csr, roots)


def test_runner_pallas_p3_path():
    src, dst = rmat_edges(8, 6, seed=9)
    csr, g = _graph_from_edges(src, dst, 256)
    roots = np.asarray([1, 2, 3], np.int32)
    res = MultiSourceBFSRunner(g, use_pallas=True).run(roots)
    _assert_matches_oracle(res.levels, csr, roots)


def test_runner_duplicate_and_single_roots():
    src, dst = rmat_edges(7, 8, seed=12)
    csr, g = _graph_from_edges(src, dst, 128)
    res = MultiSourceBFSRunner(g).run(np.asarray([5, 5, 9], np.int32))
    _assert_matches_oracle(res.levels, csr, [5, 5, 9])
    res1 = MultiSourceBFSRunner(g).run(np.asarray([5], np.int32))
    np.testing.assert_array_equal(res1.levels[0], res.levels[0])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 5), st.integers(1, 40))
def test_msbfs_property_random_graphs(seed, ef, batch):
    """Property: MS-BFS levels == per-root oracle on random RMATs."""
    src, dst = rmat_edges(7, ef, seed=seed)
    csr, g = _graph_from_edges(src, dst, 128)
    rng = np.random.default_rng(seed)
    roots = rng.choice(128, batch, replace=False)
    res = MultiSourceBFSRunner(g).run(roots)
    _assert_matches_oracle(res.levels, csr, roots)


# ---------------------------------------------------------------------------
# distributed batched path + serving entry point
# ---------------------------------------------------------------------------

def test_distributed_run_batch_matches_oracle():
    ds = get_dataset("tiny-16-4")
    pg = partition_graph(ds.csr, ds.csc, 4)     # 4 PEs on 1 device
    mesh = make_mesh((1,), ("data",))
    eng = DistributedBFS(pg, mesh, cfg=DistConfig(dispatch="bitmap"))
    roots = np.asarray([0, 1, 7, 9, 15])
    levels = eng.run_batch(roots)
    _assert_matches_oracle(levels, ds.csr, roots)
    assert eng.last_stats["batch"] == 5


def test_distributed_run_batch_matches_single_run():
    ds = get_dataset("tiny-16-4")
    pg = partition_graph(ds.csr, ds.csc, 2)
    mesh = make_mesh((1,), ("data",))
    eng = DistributedBFS(pg, mesh)
    levels = eng.run_batch(np.asarray([3]))
    np.testing.assert_array_equal(levels[0], eng.run(3))


def test_serve_bfs_batch_entry():
    from repro.launch.serve import bfs_batch, build_bfs_engine
    engine, deg = build_bfs_engine("tiny-16-4", distributed=False)
    roots = np.asarray([0, 2, 4, 6])
    ds = get_dataset("tiny-16-4")
    out = bfs_batch(roots, engine=engine, out_deg=deg)
    _assert_matches_oracle(out["levels"], ds.csr, roots)
    assert out["batch"] == 4 and out["aggregate_teps"] >= 0
