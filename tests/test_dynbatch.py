"""Dynamic-batching BFS serving driver (``repro.launch.dynbatch``).

The scheduler is driven deterministically with an injected fake clock
(no worker thread): N single-root submits inside one window must be
served by exactly ONE MS-BFS wave whose futures all match ``bfs_oracle``.
Also covers the max_batch cap, plane-slot padding, backpressure,
drain/shutdown, root validation, the threaded real-clock mode, and the
distributed engine behind the same frontend.
"""
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import (MultiSourceBFSRunner, bfs_oracle, bitmap,
                        build_local_graph, partition_graph)
from repro.core.bfs_distributed import DistConfig, DistributedBFS
from repro.graph import csr_from_edges, transpose_csr, uniform_edges
from repro.launch.dynbatch import (BatcherClosed, DynamicBatcher,
                                   Overloaded, QueueFull,
                                   engine_num_vertices)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += float(dt)


@pytest.fixture(scope="module")
def graph():
    src, dst = uniform_edges(256, 1024, seed=7)
    csr = csr_from_edges(src, dst, 256)
    return csr, build_local_graph(csr, transpose_csr(csr))


@pytest.fixture()
def engine(graph):
    return MultiSourceBFSRunner(graph[1])


# ---------------------------------------------------------------------------
# plane-slot pad/slice helpers (core)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,padded", [(1, 32), (5, 32), (31, 32), (32, 32),
                                      (33, 64), (48, 64), (64, 64)])
def test_pad_plane_slots(b, padded):
    roots = np.arange(1, b + 1, dtype=np.int64)
    slots, orig = bitmap.pad_plane_slots(roots)
    assert orig == b and slots.size == padded and slots.dtype == roots.dtype
    np.testing.assert_array_equal(slots[:b], roots)
    if padded > b:          # pad slots duplicate the first root
        assert (slots[b:] == roots[0]).all()
    rows = np.arange(padded * 3).reshape(padded, 3)
    np.testing.assert_array_equal(bitmap.slice_plane_rows(rows, orig),
                                  rows[:b])


def test_pad_plane_slots_rejects_empty():
    with pytest.raises(ValueError):
        bitmap.pad_plane_slots(np.asarray([], np.int64))


def test_pad_plane_slots_validates_fill():
    roots = np.asarray([4, 9, 2], np.int64)
    with pytest.raises(TypeError):
        bitmap.pad_plane_slots(roots, fill=1.5)
    with pytest.raises(TypeError):
        bitmap.pad_plane_slots(roots, fill=True)    # bool is not a vertex
    with pytest.raises(TypeError):
        bitmap.pad_plane_slots(roots, fill="0")
    with pytest.raises(ValueError):
        bitmap.pad_plane_slots(roots, fill=-1)
    slots, b = bitmap.pad_plane_slots(roots, fill=np.int64(7))
    assert b == 3 and (slots[3:] == 7).all()
    slots, b = bitmap.pad_plane_slots(roots, fill=0)
    assert (slots[3:] == 0).all()
    # full word: fill is validated but unused
    full = np.arange(32, dtype=np.int64)
    slots, b = bitmap.pad_plane_slots(full, fill=5)
    assert b == 32 and slots.size == 32


@pytest.mark.parametrize("b", [1, 31, 33])
def test_pad_slots_inert_in_wave_accounting(graph, engine, b):
    """Pad slots (duplicate planes) must be invisible END TO END: the
    wave's sliced levels equal the per-root oracle, WaveStats counts
    traversed edges over the REAL requests only (a padded B=1 wave must
    not report 32x the edges), and edge traffic matches an unpadded run
    of the same roots (a duplicate plane never changes the union
    frontier)."""
    from repro.core import count_traversed_edges
    csr, g = graph
    roots = np.random.default_rng(100 + b).choice(256, b,
                                                  replace=False).tolist()
    batcher = DynamicBatcher(engine, window=1.0, max_batch=64,
                             clock=FakeClock())
    futures = [batcher.submit(int(r), block=False) for r in roots]
    waves = batcher.flush()
    assert len(waves) == 1
    ws = waves[0]
    assert ws.batch == b and ws.n_slots == ((b + 31) // 32) * 32
    oracle_rows = np.stack([bfs_oracle(csr, int(r)) for r in roots])
    for f, want in zip(futures, oracle_rows):
        np.testing.assert_array_equal(f.result(), want)
    # TEPS numerator over real requests only == slice-then-count
    assert ws.traversed_edges == count_traversed_edges(
        np.asarray(engine.out_deg), oracle_rows)
    # duplicate pad planes leave the union frontier (and so the per-level
    # edge traffic) unchanged: an unpadded engine run inspects the same
    # number of edges
    res = engine.run(np.asarray(roots, np.int64))
    assert ws.edges_inspected == res.edges_inspected
    np.testing.assert_array_equal(
        bitmap.slice_plane_rows(np.vstack([oracle_rows,
                                           oracle_rows[:1].repeat(
                                               ws.n_slots - b, 0)]), b),
        oracle_rows)


@pytest.mark.parametrize("b,slots", [(1, 32), (32, 32), (33, 64)])
def test_padded_slots_never_leak_into_results(graph, engine, b, slots):
    """End-to-end pad/slice round trip through a real wave: B=1, B an
    exact word multiple (no pad at all), and B=33 padded across a word
    boundary.  Every future must equal its per-root oracle and the pad
    slots (duplicates of the first root) must never surface."""
    csr, _ = graph
    batcher = DynamicBatcher(engine, window=1.0, max_batch=64,
                             clock=FakeClock())
    roots = [int(r) for r in
             np.random.default_rng(b).choice(256, b, replace=False)]
    futures = [batcher.submit(r, block=False) for r in roots]
    waves = batcher.flush()
    assert len(waves) == 1
    wave = waves[0]
    assert wave.batch == b and wave.n_slots == slots
    for f, r in zip(futures, roots):
        lv = np.asarray(f.result(timeout=0), np.int64)
        assert lv.shape == (256,)           # one row per vertex, no slots
        np.testing.assert_array_equal(lv, bfs_oracle(csr, r))
    assert batcher.stats()["requests"] == b
    batcher.close()


# ---------------------------------------------------------------------------
# deterministic fake-clock scheduling
# ---------------------------------------------------------------------------

def test_one_window_is_exactly_one_wave_matching_oracle(graph, engine):
    """Acceptance: N submits inside one window -> ONE MS-BFS wave; every
    future's levels equal the per-root oracle."""
    csr, _ = graph
    clock = FakeClock()
    b = DynamicBatcher(engine, window=0.01, max_batch=32, clock=clock)
    roots = [0, 3, 17, 42, 199]
    futures = []
    for r in roots:
        futures.append(b.submit(r, block=False))
        clock.advance(0.001)            # arrivals spread inside the window
    assert b.pump() is None             # window not elapsed -> nothing due
    assert not any(f.done() for f in futures)
    clock.advance(0.01)                 # oldest request now past the window
    wave = b.pump()
    assert wave is not None and b.pump() is None
    assert len(b.waves) == 1 and wave.batch == len(roots)
    assert wave.n_slots == 32           # padded to one full plane word
    for f, r in zip(futures, roots):
        assert f.done() and f.wave is wave
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      bfs_oracle(csr, r))
    # latency is deterministic under the fake clock: submit -> wave cut
    assert futures[0].latency == pytest.approx(0.015)
    assert futures[-1].latency == pytest.approx(0.011)
    s = b.stats()
    assert s["waves"] == 1 and s["requests"] == 5
    assert s["traversed_edges"] == wave.traversed_edges > 0


def test_full_wave_dispatches_before_window(engine):
    clock = FakeClock()
    b = DynamicBatcher(engine, window=10.0, max_batch=4, clock=clock,
                       pad_to_plane=False)
    for r in range(7):
        b.submit(r, block=False)
    wave = b.pump()                     # cap reached: no deadline needed
    assert wave.batch == 4 and wave.n_slots == 4
    assert b.pump() is None             # 3 left, window wide open
    waves = b.flush()
    assert len(waves) == 1 and waves[0].batch == 3
    assert [w.wave_id for w in b.waves] == [0, 1]


def test_window_restarts_from_oldest_remaining(engine):
    clock = FakeClock()
    b = DynamicBatcher(engine, window=1.0, max_batch=2, clock=clock)
    b.submit(1, block=False)
    clock.advance(0.5)
    b.submit(2, block=False)
    b.submit(3, block=False)            # full wave of 2 is now due
    assert b.pump().batch == 2
    assert b.pump() is None             # root 3 aged only 0.0 of its window
    clock.advance(0.99)
    assert b.pump() is None             # 0.99 < 1.0: still waiting
    clock.advance(0.02)
    assert b.pump().batch == 1


def test_backpressure_bounded_queue(engine):
    b = DynamicBatcher(engine, window=1.0, max_pending=3, clock=FakeClock())
    for r in range(3):
        b.submit(r, block=False)
    with pytest.raises(QueueFull):
        b.submit(3, block=False)
    # manual mode never drains concurrently: block=True must also raise
    with pytest.raises(QueueFull):
        b.submit(3)
    b.flush()
    b.submit(3, block=False)            # capacity freed by the wave cut
    b.close(drain=True)


def test_close_drains_or_cancels(graph, engine):
    csr, _ = graph
    b = DynamicBatcher(engine, window=5.0, clock=FakeClock())
    f = b.submit(9, block=False)
    b.close(drain=True)                 # flushes despite the open window
    np.testing.assert_array_equal(np.asarray(f.result(timeout=0), np.int64),
                                  bfs_oracle(csr, 9))
    with pytest.raises(BatcherClosed):
        b.submit(1, block=False)

    b2 = DynamicBatcher(engine, window=5.0, clock=FakeClock())
    f2 = b2.submit(9, block=False)
    b2.close(drain=False)               # cancel instead of serving
    assert f2.done()
    with pytest.raises(BatcherClosed):
        f2.result(timeout=0)
    assert b2.stats()["waves"] == 0


def test_submit_validates_roots(engine):
    b = DynamicBatcher(engine, clock=FakeClock())
    assert engine_num_vertices(engine) == 256
    with pytest.raises(ValueError):
        b.submit(-1, block=False)
    with pytest.raises(ValueError):
        b.submit(256, block=False)
    with pytest.raises(ValueError, match="integer"):
        b.submit(5.7, block=False)      # truncation would serve root 5
    b.close()


def test_duplicate_roots_resolve_independently(graph, engine):
    csr, _ = graph
    b = DynamicBatcher(engine, clock=FakeClock())
    f1 = b.submit(5, block=False)
    f2 = b.submit(5, block=False)
    b.flush()
    want = bfs_oracle(csr, 5)
    for f in (f1, f2):
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      want)


def test_wrapper_engine_bad_root_fails_only_its_future(graph, engine):
    """An opaque wrapper engine (no .g/.pg) defeats submit-time validation;
    a bad root rejected at dispatch must not fail its co-batched wave."""
    csr, _ = graph

    class Wrapper:
        def __init__(self, inner):
            self._inner = inner

        def run_batch(self, roots):
            return self._inner.run(np.asarray(roots)).levels

    b = DynamicBatcher(Wrapper(engine), window=1.0, clock=FakeClock())
    assert b.num_vertices is None and b.out_deg is None
    good = b.submit(3, block=False)
    bad = b.submit(999, block=False)       # accepted: |V| unknown here
    good2 = b.submit(7, block=False)
    b.flush()
    with pytest.raises(ValueError):
        bad.result(timeout=0)
    for f, r in ((good, 3), (good2, 7)):
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      bfs_oracle(csr, r))
    s = b.stats()
    assert s["errors"] >= 1
    assert "aggregate_teps" not in s       # no out_deg -> TEPS unknowable
    b.close()


# ---------------------------------------------------------------------------
# threaded real-clock mode + distributed engine
# ---------------------------------------------------------------------------

def test_threaded_serving_matches_oracle(graph, engine):
    csr, _ = graph
    roots = [2, 50, 100, 150, 200, 250]
    with DynamicBatcher(engine, window=0.05) as b:
        futures = [b.submit(r) for r in roots]
        levels = [f.result(timeout=120.0) for f in futures]
    for lv, r in zip(levels, roots):
        np.testing.assert_array_equal(np.asarray(lv, np.int64),
                                      bfs_oracle(csr, r))
    s = b.stats()
    assert 1 <= s["waves"] <= len(roots) and s["requests"] == len(roots)
    assert s["latency_p99"] >= s["latency_p50"] > 0


def test_distributed_engine_behind_batcher():
    src, dst = uniform_edges(64, 256, seed=3)
    csr = csr_from_edges(src, dst, 64)
    pg = partition_graph(csr, transpose_csr(csr), 4)
    mesh = make_mesh((1,), ("data",))
    eng = DistributedBFS(pg, mesh, cfg=DistConfig(dispatch="bitmap"))
    deg = np.diff(csr.indptr)
    b = DynamicBatcher(eng, out_deg=deg, window=0.01, clock=FakeClock())
    roots = [0, 13, 63]
    futures = [b.submit(r, block=False) for r in roots]
    waves = b.flush()
    assert len(waves) == 1 and waves[0].n_slots == 32
    assert waves[0].traversed_edges > 0
    for f, r in zip(futures, roots):
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      bfs_oracle(csr, r))
    b.close()


# ---------------------------------------------------------------------------
# fault tolerance: typed futures, drain under failure, supervised waves
# ---------------------------------------------------------------------------

class AlwaysDown:
    """Transiently-failing engine (every wave raises RuntimeError)."""

    last_stats = {}

    def run_batch(self, roots):
        raise RuntimeError("engine down")


def test_future_done_and_exception_accessors(graph, engine):
    csr, _ = graph
    b = DynamicBatcher(engine, window=1.0, clock=FakeClock())
    f = b.submit(5, block=False)
    assert not f.done()
    assert f.exception() is None            # pending: poll returns None
    assert f.exception(timeout=0.01) is None
    b.flush()
    assert f.done() and f.exception() is None       # success: still None
    np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                  bfs_oracle(csr, 5))
    b.close()


def test_failed_future_raises_typed_error_immediately():
    """A resolved-with-error future must raise at once, not ride out the
    caller's timeout (the old bug: error-resolution didn't set the event,
    so result(timeout=30) blocked the full 30s)."""
    import time as _time

    b = DynamicBatcher(AlwaysDown(), window=1.0, clock=FakeClock())
    f = b.submit(3, block=False)
    b.flush()
    assert f.done()
    assert isinstance(f.exception(), RuntimeError)
    t0 = _time.perf_counter()
    with pytest.raises(RuntimeError):
        f.result(timeout=30.0)
    assert _time.perf_counter() - t0 < 5.0
    b.close()


def test_drain_resolves_every_future_with_failing_engine_legacy():
    """close(drain=True) with a permanently failing engine must terminate
    and resolve EVERY future with a typed error (no unbounded retry)."""
    b = DynamicBatcher(AlwaysDown(), window=1.0, clock=FakeClock())
    futures = [b.submit(r, block=False) for r in range(5)]
    b.close(drain=True)
    for f in futures:
        assert f.done()
        assert isinstance(f.exception(), RuntimeError)
    s = b.stats()
    assert s["errors"] >= 1 and s["requests"] == 0


def test_drain_resolves_every_future_with_failing_engine_supervised():
    from repro.ft import EngineSupervisor, WaveAbandoned

    sup = EngineSupervisor(AlwaysDown(), max_retries=1, backoff=0.0,
                           watchdog=False)
    b = DynamicBatcher(sup, window=1.0, clock=FakeClock())
    futures = [b.submit(r, block=False) for r in range(4)]
    b.close(drain=True)
    for f in futures:
        assert f.done()
        assert isinstance(f.exception(), WaveAbandoned)
    s = b.stats()
    assert s["requests_failed"] == 4
    assert s["fault_tolerance"]["retries"] == 1


def test_legacy_deterministic_fault_retries_singletons_once(graph, engine):
    """Unsupervised dispatch splits a deterministically-failing wave into
    singleton retries EXACTLY once — a singleton that still fails resolves
    with its error instead of re-enqueueing forever."""
    csr, _ = graph

    class BadRootEngine:
        last_stats = {}

        def __init__(self, inner):
            self._inner = inner

        def run_batch(self, roots):
            if 999 in np.asarray(roots).tolist():
                raise ValueError("root out of range")
            return self._inner.run(np.asarray(roots)).levels

    b = DynamicBatcher(BadRootEngine(engine), window=1.0, clock=FakeClock())
    good = b.submit(3, block=False)
    bad = b.submit(999, block=False)
    b.close(drain=True)                     # wave + singleton retries
    assert good.done() and bad.done()
    with pytest.raises(ValueError):
        bad.result(timeout=0)
    np.testing.assert_array_equal(np.asarray(good.result(), np.int64),
                                  bfs_oracle(csr, 3))


def test_supervised_wave_quarantines_poison_and_serves_rest(graph, engine):
    """EngineSupervisor behind the batcher: per-request outcomes — the
    poisoned root fails typed, co-batched requests get correct levels."""
    from repro.ft import (EngineSupervisor, FaultyEngine, PoisonedRoot,
                          RequestQuarantined)

    csr, _ = graph
    sup = EngineSupervisor(FaultyEngine(engine, poisoned_roots=[42]),
                           backoff=0.0, watchdog=False)
    b = DynamicBatcher(sup, out_deg=np.asarray(engine.out_deg),
                       window=1.0, clock=FakeClock())
    roots = [0, 3, 42, 17, 99]
    futures = [b.submit(r, block=False) for r in roots]
    waves = b.flush()
    assert len(waves) == 1
    ws = waves[0]
    assert ws.failed == 1 and ws.quarantined == [42]
    assert ws.traversals > 1                # bisection sub-waves counted
    for f, r in zip(futures, roots):
        if r == 42:
            exc = f.exception()
            assert isinstance(exc, RequestQuarantined)
            assert isinstance(exc.__cause__, PoisonedRoot)
        else:
            np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                          bfs_oracle(csr, r))
    s = b.stats()
    assert s["requests"] == 4 and s["requests_failed"] == 1
    assert s["fault_tolerance"]["quarantined"] == [42]
    assert s["traversed_edges"] > 0         # TEPS over the served four
    b.close()


# ---------------------------------------------------------------------------
# multi-word waves (max_batch spanning several plane words)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,slots", [(33, 64), (64, 64), (96, 96)])
def test_multiword_wave_pads_and_slices_without_leaks(graph, engine, b,
                                                      slots):
    """A wave wider than one plane word: pad slots must not inflate the
    wave's TEPS numerator and must never leak into any future's row."""
    from repro.core import count_traversed_edges
    csr, _ = graph
    batcher = DynamicBatcher(engine, window=1.0, max_batch=96,
                             clock=FakeClock())
    rng = np.random.default_rng(1000 + b)
    roots = [int(r) for r in rng.choice(256, b, replace=(b > 256))]
    futures = [batcher.submit(r, block=False) for r in roots]
    waves = batcher.flush()
    assert len(waves) == 1
    ws = waves[0]
    assert ws.batch == b and ws.n_slots == slots
    oracle_rows = np.stack([bfs_oracle(csr, r) for r in roots])
    for f, want in zip(futures, oracle_rows):
        lv = np.asarray(f.result(timeout=0), np.int64)
        assert lv.shape == (256,)
        np.testing.assert_array_equal(lv, want)
    # TEPS numerator over the REAL requests only, not the padded slots
    assert ws.traversed_edges == count_traversed_edges(
        np.asarray(engine.out_deg), oracle_rows)
    assert batcher.stats()["requests"] == b
    batcher.close()


def test_supervised_multiword_bisection_keeps_future_order(graph, engine):
    """Futures <-> outcomes ordering through a supervised MULTI-WORD wave
    that bisects: with a poison mid-wave at B=64, every clean future must
    resolve with ITS OWN root's levels (bisection reorders sub-waves
    internally; the mapping back to futures must not)."""
    from repro.ft import EngineSupervisor, FaultyEngine, RequestQuarantined

    csr, _ = graph
    sup = EngineSupervisor(FaultyEngine(engine, poisoned_roots=[42]),
                           backoff=0.0, watchdog=False)
    b = DynamicBatcher(sup, out_deg=np.asarray(engine.out_deg),
                       window=1.0, max_batch=96, clock=FakeClock())
    roots = list(range(64))                 # includes poison root 42
    futures = [b.submit(r, block=False) for r in roots]
    waves = b.flush()
    assert len(waves) == 1
    ws = waves[0]
    assert ws.batch == 64 and ws.n_slots == 64
    assert ws.failed == 1 and ws.quarantined == [42]
    for f, r in zip(futures, roots):
        if r == 42:
            assert isinstance(f.exception(), RequestQuarantined)
        else:
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=0), np.int64),
                bfs_oracle(csr, r))
    b.close()


# ---------------------------------------------------------------------------
# accounting bugfix regressions (SLO-blind percentiles, injected-clock
# timeout, busy-seconds undercount)
# ---------------------------------------------------------------------------

def test_failed_wave_latencies_reach_percentiles_legacy():
    """Regression: the legacy failure path never populated ws.latencies
    and stats() filtered failed waves out, so p99 under faults excluded
    exactly the requests that blew the SLO."""
    clock = FakeClock()
    b = DynamicBatcher(AlwaysDown(), window=1.0, clock=clock)
    futures = [b.submit(r, block=False) for r in range(3)]
    clock.advance(2.0)                      # requests age before the wave
    b.flush()
    for f in futures:
        assert f.done() and f.latency == pytest.approx(2.0)
        assert f.wave is not None
    s = b.stats()
    assert s["errors"] == 1
    assert s["latency_p99"] == pytest.approx(2.0)
    assert s["latency_p50"] == pytest.approx(2.0)
    b.close()


def test_failed_wave_latencies_reach_percentiles_supervised():
    """Regression for the supervised path: ws.latencies were populated
    but stats() dropped any wave with error set before pooling."""
    from repro.ft import EngineSupervisor

    clock = FakeClock()
    sup = EngineSupervisor(AlwaysDown(), max_retries=0, backoff=0.0,
                           watchdog=False)
    b = DynamicBatcher(sup, window=1.0, clock=clock)
    futures = [b.submit(r, block=False) for r in range(4)]
    clock.advance(3.0)
    b.flush()
    assert all(f.done() for f in futures)
    s = b.stats()
    assert s["requests_failed"] == 4
    assert s["latency_p99"] == pytest.approx(3.0)
    b.close()


def test_submit_timeout_runs_on_injected_clock(engine):
    """Regression: submit(block=True, timeout=) used raw time.monotonic
    for its deadline, so a fake-clock batcher with a worker thread had
    undefined timeout semantics.  Advancing the FAKE clock past the
    timeout must raise QueueFull promptly (wall time barely moves)."""
    import threading as _threading
    import time as _time

    clock = FakeClock()
    b = DynamicBatcher(engine, window=1e6, max_pending=1, clock=clock,
                       start=True)         # worker thread + fake clock
    b.submit(0, block=False)               # queue now at capacity

    def expire():
        _time.sleep(0.3)
        clock.advance(10.0)                # past t_submit + timeout
        with b._cond:
            b._cond.notify_all()

    t = _threading.Thread(target=expire, daemon=True)
    t.start()
    t0 = _time.perf_counter()
    with pytest.raises(QueueFull):
        b.submit(1, timeout=5.0)           # 5 FAKE seconds, not wall
    assert _time.perf_counter() - t0 < 4.0
    t.join()
    b.close(drain=True)


def test_busy_seconds_accrue_for_failed_waves(graph):
    """Regression: _record skipped busy-seconds for error waves, so
    lifetime aggregate TEPS was inflated under chaos (edges / too-small
    denominator)."""
    import time as _time

    class SlowDown:
        last_stats = {}

        def run_batch(self, roots):
            _time.sleep(0.02)              # burn real engine time
            raise RuntimeError("engine down")

    b = DynamicBatcher(SlowDown(), out_deg=np.ones(256, np.int64),
                       window=1.0, clock=FakeClock())
    for r in range(3):
        b.submit(r, block=False)
    b.flush()
    s = b.stats()
    assert s["errors"] == 1
    assert s["busy_seconds"] >= 0.02       # the failed wave's engine time
    assert s["busy_seconds"] == pytest.approx(
        sum(w.seconds for w in b.waves), abs=1e-4)   # stats() rounds
    assert s["aggregate_teps"] == 0.0      # 0 edges / REAL busy time
    b.close()


# ---------------------------------------------------------------------------
# SLO-aware cutting: deadlines, priorities, preemption, miss accounting
# ---------------------------------------------------------------------------

def test_submit_rejects_nonpositive_deadline(engine):
    b = DynamicBatcher(engine, clock=FakeClock())
    with pytest.raises(ValueError):
        b.submit(1, block=False, deadline=0.0)
    with pytest.raises(ValueError):
        b.submit(1, block=False, deadline=-1.0)
    b.close(drain=False)


def test_deadline_preempts_window(graph, engine):
    """An urgent request must cut the wave EARLY: before its deadline
    minus the margin, not at the (much later) window expiry."""
    csr, _ = graph
    clock = FakeClock()
    b = DynamicBatcher(engine, window=10.0, max_batch=32, clock=clock,
                       slo_margin=0.5)
    f = b.submit(5, block=False, deadline=1.0)
    assert b.pump() is None                 # 0 < 1.0 - 0.5: not yet
    clock.advance(0.6)                      # past deadline - margin
    ws = b.pump()
    assert ws is not None and ws.preempted
    assert ws.deadline_requests == 1 and ws.slo_misses == 0
    assert f.slo_miss is False              # resolved at t=0.6 < 1.0
    np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                  bfs_oracle(csr, 5))
    s = b.stats()
    assert s["slo_requests"] == 1 and s["slo_miss_rate"] == 0.0
    b.close()


def test_late_resolution_counts_as_slo_miss(engine):
    clock = FakeClock()
    b = DynamicBatcher(engine, window=0.1, clock=clock, slo_margin=0.0)
    f = b.submit(5, block=False, deadline=0.5)
    clock.advance(1.0)                      # deadline already blown
    ws = b.pump()
    assert ws.deadline_requests == 1 and ws.slo_misses == 1
    assert f.slo_miss is True
    assert f.done() and f.exception() is None   # late but correct
    s = b.stats()
    assert s["slo_misses"] == 1 and s["slo_miss_rate"] == 1.0
    b.close()


def test_failed_request_with_deadline_is_a_miss(engine):
    """A typed failure inside the SLO window is still a miss — the
    client did not get the answer it asked for in time."""
    b = DynamicBatcher(AlwaysDown(), window=1.0, clock=FakeClock())
    f = b.submit(3, block=False, deadline=100.0)
    b.flush()
    assert isinstance(f.exception(), RuntimeError)
    assert f.slo_miss is True
    s = b.stats()
    assert s["slo_requests"] == 1 and s["slo_miss_rate"] == 1.0
    b.close()


def test_wave_cut_orders_by_priority_then_deadline(graph, engine):
    """Urgency-first cutting: priority tier first, oldest deadline next,
    arrival order last — a late urgent request still makes the wave."""
    csr, _ = graph
    clock = FakeClock()
    b = DynamicBatcher(engine, window=100.0, max_batch=2, clock=clock)
    f_plain = b.submit(1, block=False)                   # no SLO
    f_loose = b.submit(2, block=False, deadline=5.0)
    f_tight = b.submit(3, block=False, deadline=1.0)     # latest arrival
    ws = b.pump()                           # full wave (max_batch=2)
    assert ws.batch == 2
    # the two deadline carriers ran; the plain request waits
    assert f_tight.done() and f_loose.done() and not f_plain.done()
    b.flush()
    for f, r in ((f_plain, 1), (f_loose, 2), (f_tight, 3)):
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      bfs_oracle(csr, r))
    b.close()


def test_priority_beats_deadline_in_cut_order(engine):
    clock = FakeClock()
    b = DynamicBatcher(engine, window=100.0, max_batch=1, clock=clock)
    f_dl = b.submit(1, block=False, deadline=0.5)
    f_hi = b.submit(2, block=False, priority=-1)
    ws = b.pump()                           # full (max_batch=1)
    assert ws.batch == 1
    assert f_hi.done() and not f_dl.done()  # priority tier wins
    b.close(drain=True)


# ---------------------------------------------------------------------------
# pipelined mode (cutter / dispatcher / finisher stages)
# ---------------------------------------------------------------------------

def test_pipeline_requires_threaded_mode(engine):
    with pytest.raises(ValueError):
        DynamicBatcher(engine, clock=FakeClock(), pipeline=True)


def test_pipelined_serving_matches_oracle(graph, engine):
    """Real-clock pipelined mode: the three stages hand off through
    queues and every future still matches its per-root oracle."""
    csr, _ = graph
    roots = [2, 50, 100, 150, 200, 250, 33, 77]
    with DynamicBatcher(engine, window=0.02, max_batch=64,
                        pipeline=True) as b:
        futures = [b.submit(r) for r in roots]
        levels = [f.result(timeout=120.0) for f in futures]
    for lv, r in zip(levels, roots):
        np.testing.assert_array_equal(np.asarray(lv, np.int64),
                                      bfs_oracle(csr, r))
    s = b.stats()
    assert s["pipeline"] is True
    assert s["requests"] == len(roots)
    assert s["engine_idle_seconds"] >= 0.0
    assert s["latency_p999"] >= s["latency_p99"] >= s["latency_p50"]


def test_pipelined_supervised_chaos_resolves_everything(graph, engine):
    """Pipelined batcher over a supervised faulty engine: typed errors
    still resolve through the finisher stage, nothing hangs."""
    from repro.ft import EngineSupervisor, FaultyEngine, RequestQuarantined

    csr, _ = graph
    sup = EngineSupervisor(FaultyEngine(engine, poisoned_roots=[42]),
                           backoff=0.0, watchdog=False)
    with DynamicBatcher(sup, out_deg=np.asarray(engine.out_deg),
                        window=0.02, max_batch=64, pipeline=True) as b:
        futures = [b.submit(r) for r in [3, 42, 17, 99]]
        for f in futures:
            f.exception(timeout=120.0)      # wait for resolution
    for f, r in zip(futures, [3, 42, 17, 99]):
        if r == 42:
            assert isinstance(f.exception(), RequestQuarantined)
        else:
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=0), np.int64),
                bfs_oracle(csr, r))
    assert b.stats()["requests_failed"] == 1


# ---------------------------------------------------------------------------
# Admission control (shed), health streaks, pool-support plumbing
# ---------------------------------------------------------------------------

class TimedEngine:
    """Wraps a runner, charging a fixed fake-clock cost per wave so the
    batcher's EWMA service estimate is deterministic."""

    def __init__(self, inner, clock, cost=0.2, fails_left=0):
        self.inner = inner
        self.clock = clock
        self.cost = float(cost)
        self.fails_left = int(fails_left)
        self.num_vertices = inner.num_vertices

    def run_batch(self, roots, **kw):
        self.clock.advance(self.cost)
        if self.fails_left > 0:
            self.fails_left -= 1
            raise RuntimeError("injected engine failure")
        return self.inner.run_batch(roots, **kw)


def test_service_hint_primes_estimated_delay(engine):
    clock = FakeClock()
    b = DynamicBatcher(engine, window=1.0, max_batch=4, clock=clock,
                       service_hint=1.0)
    assert b.estimated_delay() == pytest.approx(1.0)    # idle: one wave
    b.submit(3, block=False)
    b.submit(5, block=False)
    assert b.estimated_delay() == pytest.approx(1.5)    # 1.0 x (1 + 2/4)
    b.flush()
    b.close()
    with pytest.raises(ValueError):
        DynamicBatcher(engine, clock=FakeClock(), service_hint=-0.5)


def test_ewma_tracks_measured_wave_service(graph, engine):
    clock = FakeClock()
    timed = TimedEngine(engine, clock, cost=0.2)
    b = DynamicBatcher(timed, window=1.0, clock=clock)
    assert b.estimated_delay() == 0.0       # unprimed: never sheds cold
    b.submit(3, block=False)
    b.flush()
    assert b.estimated_delay() == pytest.approx(0.2)    # first wave primes
    b.close()


def test_shed_rejects_doomed_deadline_with_typed_overloaded(graph, engine):
    """Admission control: a deadline the backlog already dooms is refused
    up front so it fails in microseconds, not after a full queue wait."""
    clock = FakeClock()
    b = DynamicBatcher(engine, window=1.0, max_batch=4, clock=clock,
                       shed=True, service_hint=1.0)
    ok = b.submit(3, block=False, deadline=10.0)        # 1.0s est <= 10s
    with pytest.raises(Overloaded):
        b.submit(5, block=False, deadline=0.4)          # 1.25s est > 0.4s
    b.submit(7, block=False)                # no deadline: never shed
    b.flush()
    assert ok.exception() is None
    s = b.stats()
    assert s["shed"] == 1 and s["requests"] == 2
    b.close()


def test_shed_off_queues_doomed_deadline(engine):
    b = DynamicBatcher(engine, window=1.0, clock=FakeClock(),
                       service_hint=5.0)   # shed=False (default)
    f = b.submit(3, block=False, deadline=0.01)
    b.flush()
    assert f.done() and "shed" not in b.stats()
    b.close()


def test_cancel_pending_pops_without_resolving(graph, engine):
    csr, _ = graph
    b = DynamicBatcher(engine, window=1.0, clock=FakeClock())
    futs = [b.submit(r, block=False, deadline=5.0) for r in (3, 5, 9)]
    popped = b.cancel_pending()
    assert popped == futs and b.backlog() == 0
    assert not any(f.done() for f in popped)
    assert b.flush() == []                  # queue really is empty
    # the pool's redispatch path: transplant onto another batcher with
    # submit-time deadline/clock state intact
    b2 = DynamicBatcher(engine, window=1.0, clock=FakeClock())
    for f in popped:
        b2._submit_future(f)
    b2.flush()
    for f, r in zip(popped, (3, 5, 9)):
        assert f.t_deadline == 5.0
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      bfs_oracle(csr, r))
    b.close()
    b2.close()


def test_submit_future_respects_capacity_and_close(engine):
    b = DynamicBatcher(engine, window=1.0, max_pending=1,
                       clock=FakeClock())
    f = b.submit(3, block=False)
    b.cancel_pending()
    b.submit(5, block=False)
    with pytest.raises(QueueFull):
        b._submit_future(f)
    b.flush()
    b.close()
    with pytest.raises(BatcherClosed):
        b._submit_future(f)


def test_consecutive_failures_streak_resets_on_success(graph, engine):
    clock = FakeClock()
    timed = TimedEngine(engine, clock, fails_left=2)
    b = DynamicBatcher(timed, window=1.0, clock=clock)
    for want in (1, 2):
        b.submit(3, block=False)
        b.flush()
        assert b.consecutive_failures == want
    assert b.stats()["consecutive_failures"] == 2
    b.submit(3, block=False)                # engine healthy again
    b.flush()
    assert b.consecutive_failures == 0
    assert "consecutive_failures" not in b.stats()
    b.close()


def test_failure_handler_takes_ownership_of_failing_futures(graph, engine):
    """A True-returning handler owns the future: the batcher neither
    resolves nor books it, and the streak still advances (the pool's
    eviction signal must see every engine failure)."""
    clock = FakeClock()
    handled = []

    def handler(fut, exc):
        handled.append((fut, exc))
        return len(handled) == 1            # own the first, decline later

    timed = TimedEngine(engine, clock, fails_left=2)
    b = DynamicBatcher(timed, window=1.0, clock=clock,
                       failure_handler=handler)
    f1 = b.submit(3, block=False)
    b.flush()
    assert not f1.done()                    # handed off, not resolved
    f2 = b.submit(5, block=False)
    b.flush()
    assert f2.done()                        # handler declined: fails here
    assert isinstance(f2.exception(), RuntimeError)
    assert [f for f, _ in handled] == [f1, f2]
    assert b.consecutive_failures == 2
    assert b.stats()["requests_failed"] == 1    # only the declined one
    f1._fail(RuntimeError("resolved by the test, standing in for a pool"))
    b.close()


def test_failure_handler_exception_is_contained(graph, engine):
    clock = FakeClock()
    timed = TimedEngine(engine, clock, fails_left=1)
    b = DynamicBatcher(timed, window=1.0, clock=clock,
                       failure_handler=lambda f, e: 1 / 0)
    f = b.submit(3, block=False)
    b.flush()                               # handler blew up: treat as False
    assert f.done() and isinstance(f.exception(), RuntimeError)
    b.close()
