"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret mode):
shape/dtype/block sweeps + hypothesis property runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas


def _run(bh, s, hd, bq, bk, causal, dt, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (bh, s, hd), dt)
    k = jax.random.normal(ks[1], (bh, s, hd), dt)
    v = jax.random.normal(ks[2], (bh, s, hd), dt)
    o = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                               block_k=bk)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


@pytest.mark.parametrize("s,bq,bk", [(256, 128, 128), (256, 64, 256),
                                     (512, 128, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_blocks(s, bq, bk, causal):
    _run(2, s, 64, bq, bk, causal, jnp.float32)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hd", [32, 64, 128])
def test_flash_dtypes_headdims(dt, hd):
    _run(1, 256, hd, 128, 128, True, dt)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 30))
def test_flash_property(seed):
    _run(2, 256, 32, 128, 128, True, jnp.float32, seed=seed % 9973)
