"""Chaos acceptance: the fault-tolerant serving stack on a real graph.

A fake-clock ``DynamicBatcher`` drives ``EngineSupervisor`` over a
``FaultyEngine`` wrapping the real MS-BFS runner on rmat16-16, with a
deterministic fault mix — an injected kernel fault, one stuck wave that
trips the watchdog, and one poisoned root isolated by bisection — over
96 single-root requests.  Every future must resolve (levels or a typed
error), every non-poisoned answer must equal the fault-free reference,
the poison must quarantine within the ceil(log2 B)+1 bisection bound,
and a forced Pallas failure must demote to the jnp fallback with
oracle-matching rows.
"""
import math

import numpy as np
import pytest

from repro.core import MultiSourceBFSRunner, build_local_graph
from repro.ft import (EngineSupervisor, FaultPlan, FaultyEngine,
                      RequestQuarantined)
from repro.graph import get_dataset
from repro.launch.dynbatch import DynamicBatcher

GRAPH = "rmat16-16"
B = 32                   # wave width = one plane word
REQUESTS = 3 * B         # >= 64, three full waves


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += float(dt)


@pytest.fixture(scope="module")
def served():
    """Graph + warmed runner + request stream + fault-free reference."""
    ds = get_dataset(GRAPH)
    g = build_local_graph(ds.csr, ds.csc)
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(0)
    reachable = np.flatnonzero(deg > 0)
    roots = rng.choice(reachable, REQUESTS, replace=True).astype(np.int64)
    poison = int(np.setdiff1d(reachable, roots)[0])
    roots[B + B // 2] = poison          # one poisoned request, wave 2
    runner = MultiSourceBFSRunner(g)
    runner.run(np.resize(roots, B))     # warm the packed 32-slot shape
    ref = {}
    for lo in range(0, REQUESTS, B):
        wave = np.resize(roots[lo:lo + B], B)
        for r, row in zip(wave, runner.run(wave).levels):
            ref[int(r)] = np.asarray(row, np.int64).copy()
    return dict(runner=runner, deg=deg, roots=roots, poison=poison,
                ref=ref)


def test_chaos_stream_resolves_everything_correctly(served):
    """96 requests under kernel fault + stuck wave + poisoned root."""
    runner, deg = served["runner"], served["deg"]
    roots, poison, ref = served["roots"], served["poison"], served["ref"]

    chaos = FaultyEngine(runner, FaultPlan(), poisoned_roots=[poison],
                         stall_seconds=2.5)
    sup = EngineSupervisor(chaos, max_retries=3, backoff=0.01,
                           wave_deadline=1.0, degrade=False)
    clock = FakeClock()
    b = DynamicBatcher(sup, out_deg=deg, window=1.0, max_batch=B,
                       clock=clock)
    futures = []
    # wave 1: an injected kernel fault on its first traversal (retried)
    chaos.plan = FaultPlan([(chaos.calls, "kernel")])
    futures += [b.submit(int(r), block=False) for r in roots[:B]]
    assert len(b.flush()) == 1
    # wave 2: contains the poisoned root (isolated by bisection)
    futures += [b.submit(int(r), block=False) for r in roots[B:2 * B]]
    assert len(b.flush()) == 1
    # wave 3: stuck — stalls past the watchdog deadline, retried clean
    chaos.plan = FaultPlan([(chaos.calls, "stuck")])
    futures += [b.submit(int(r), block=False) for r in roots[2 * B:]]
    assert len(b.flush()) == 1
    b.close()
    z = sup._zombie                     # the abandoned stuck traversal
    if z is not None:
        z.join(30.0)

    # every future resolved: levels or a typed error, zero hangs
    assert all(f.done() for f in futures)
    n_quarantined = 0
    for f, r in zip(futures, roots.tolist()):
        exc = f.exception()
        if int(r) == poison:
            assert isinstance(exc, RequestQuarantined)
            n_quarantined += 1
        else:
            # differential: non-poisoned answers match fault-free levels
            assert exc is None, f"clean root {r} failed: {exc!r}"
            np.testing.assert_array_equal(
                np.asarray(f.result(), np.int64), ref[int(r)])
    assert n_quarantined == 1

    s = b.stats()
    assert s["requests"] == REQUESTS - 1 and s["requests_failed"] == 1
    ft = s["fault_tolerance"]
    assert ft["quarantined"] == [poison]
    assert ft["timeouts"] >= 1          # the stuck wave tripped the watchdog
    assert ft["retries"] >= 2           # kernel fault + stuck both retried
    assert chaos.plan.pending() == {}   # every scheduled fault fired
    # the poison wave stayed within the bisection budget.  The stuck
    # wave's zombie thread can hold the engine lock into the retry, so a
    # retry may ALSO trip the watchdog — each observed timeout accounts
    # for one fault wave (wall-clock-racy otherwise).
    bound = math.ceil(math.log2(B)) + 1
    assert ft["fault_waves"] <= 1 + ft["timeouts"] + bound
    assert ft["bisections"] >= 1

    # wave-level accounting surfaced through the batcher
    poison_waves = [w for w in b.waves if w.quarantined]
    assert len(poison_waves) == 1
    assert poison_waves[0].quarantined == [poison]
    assert poison_waves[0].failed == 1
    # the stuck wave (last cut) tripped the watchdog and still recovered
    # every request.  Other waves may record incidental timeouts under
    # load (see the fault-wave bound comment above) — don't assert they
    # can't, only that the injected stall was caught and survived.
    stuck_wave = list(b.waves)[-1]
    assert stuck_wave.timeouts >= 1 and stuck_wave.failed == 0


def test_bisection_bound_on_real_wave(served):
    """Poison alone in a full clean wave: isolated in exactly the fault
    path down the bisection tree — ceil(log2 B)+1 faulted traversals."""
    runner, poison, ref = served["runner"], served["poison"], served["ref"]
    clean = np.asarray([r for r in sorted(ref) if r != poison], np.int64)
    wave_roots = np.resize(clean, B)
    wave_roots[B // 2] = poison
    sup = EngineSupervisor(FaultyEngine(runner, poisoned_roots=[poison]),
                           watchdog=False, backoff=0.0)
    wave = sup.run_wave(wave_roots)
    bound = math.ceil(math.log2(B)) + 1
    assert wave.fault_waves == bound        # poison rides one root-to-leaf path
    assert wave.quarantined == [poison]
    assert wave.n_ok == B - 1
    for o in wave.outcomes:
        if o.root != poison:
            np.testing.assert_array_equal(
                np.asarray(o.levels, np.int64), ref[o.root])


def test_forced_pallas_failure_demotes_to_jnp_matching_oracle(served):
    """break_pallas: the ladder steps use_pallas off mid-wave and the jnp
    fallback's rows equal the fault-free reference."""
    runner, poison, ref = served["runner"], served["poison"], served["ref"]
    clean = np.asarray([r for r in sorted(ref) if r != poison],
                       np.int64)[:B]
    prev = runner.use_pallas
    runner.use_pallas = True
    try:
        sup = EngineSupervisor(FaultyEngine(runner, break_pallas=True),
                               max_retries=3, backoff=0.0, watchdog=False)
        wave = sup.run_wave(clean)
    finally:
        runner.use_pallas = prev
    assert wave.demotions == ["pallas->jnp"]
    assert wave.n_failed == 0
    for o in wave.outcomes:
        np.testing.assert_array_equal(np.asarray(o.levels, np.int64),
                                      ref[o.root])


def test_watchdog_deadline_tracks_timer_on_real_waves(served):
    """With no explicit deadline, the watchdog calibrates from the
    StepTimer's running median of real wave durations."""
    runner = served["runner"]
    roots = np.resize(np.asarray(sorted(served["ref"])[:5], np.int64), B)
    sup = EngineSupervisor(runner, watchdog=True)
    assert sup.current_deadline() is None       # cold: compile-safe
    for _ in range(3):
        assert sup.run_wave(roots).n_ok == B
    dl = sup.current_deadline()
    med = sup.timer.median()
    assert dl is not None and med is not None
    assert dl >= sup.timer.k * med or dl == pytest.approx(sup.min_deadline)
