"""Property-based tests (hypothesis) on framework invariants."""
from repro.testing import given, settings, strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import partition_graph, reindex, unreindex
from repro.graph.csr import csr_from_edges, transpose_csr
from repro.models import moe
from repro.models.psharding import RULES, spec_for


class _FakeMesh:
    def __init__(self, shape):
        self._shape = dict(shape)

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


@settings(max_examples=50, deadline=None)
@given(st.tuples(st.integers(1, 512), st.integers(1, 512),
                 st.integers(1, 512)),
       st.sampled_from([("pod", 2), ("data", 16), ("model", 16)]))
def test_spec_for_divisibility(shape, axis):
    """Any axis spec_for assigns must divide the dim evenly."""
    mesh = _FakeMesh([("pod", 2), ("data", 16), ("model", 16)])
    spec = spec_for(shape, ("batch", "seq", "ff"), mesh)
    if spec is None:
        return
    sizes = dict(mesh.shape)
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([sizes[a] for a in axes]))
        assert shape[dim] % total == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(1, 8),
       st.sampled_from(["hash", "contiguous"]), st.integers(0, 2 ** 31 - 1))
def test_partition_covers_all_edges(scale, q, scheme, seed):
    """Every edge of the input graph appears in exactly one shard."""
    n = 1 << scale
    rng = np.random.default_rng(seed)
    m = max(2 * n, 8)
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    csr = csr_from_edges(src, dst, n)
    csc = transpose_csr(csr)
    pg = partition_graph(csr, csc, q, scheme=scheme)
    # total real (non-pad) edge slots == |E| for both CSR and CSC shards
    assert int((pg.out_indices >= 0).sum()) == csr.indices.size
    assert int((pg.in_indices >= 0).sum()) == csc.indices.size
    # per-shard indptr accounts for every owned vertex's full list
    assert int(pg.out_indptr[:, -1].sum()) == csr.indices.size


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(0, 10_000))
def test_reindex_roundtrip(q, v):
    vl = 32 * max(1, (10_000 // q) // 32 + 1)
    g = reindex(np.asarray([v]), q, vl)
    assert unreindex(g, q, vl)[0] == v


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(0, 2 ** 30),
       st.floats(0.3, 2.0))
def test_moe_dispatch_engines_agree(e, k, seed, capf):
    """gather == onehot for arbitrary expert counts / top-k / capacity."""
    k = min(k, e)
    d, f = 16, 24
    p = moe.moe_params(jax.random.key(seed % 1000), d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.key(seed % 997), (2, 12, d),
                          jnp.float32)
    y1, a1 = moe.moe_forward(x, p, top_k=k, chunk=8, capacity_factor=capf,
                             dispatch="onehot")
    y2, a2 = moe.moe_forward(x, p, top_k=k, chunk=8, capacity_factor=capf,
                             dispatch="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    assert abs(float(a1 - a2)) < 1e-5


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 30))
def test_ssd_chunked_matches_decode(seed):
    """Chunked SSD forward == sequential single-step recurrence."""
    from repro.models import ssm
    B, S, d, expand, hd, N, cw = 1, 19, 8, 2, 4, 4, 4
    p = ssm.ssm_params(jax.random.key(seed % 1000), d, expand, hd, N, cw,
                       jnp.float32)
    x = jax.random.normal(jax.random.key(seed % 991), (B, S, d),
                          jnp.float32) * 0.3
    y_chunk = ssm.ssm_forward(x, p, expand=expand, head_dim=hd, state=N,
                              chunk=8)
    cache = ssm.ssm_init_cache(B, d, expand, hd, N, cw, jnp.float32)
    ys = []
    for t in range(S):
        yt, cache = ssm.ssm_decode(x[:, t:t + 1], p, cache, expand=expand,
                                   head_dim=hd, state=N)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.concatenate(ys, 1)),
                               atol=5e-4)
