"""The loop-aware HLO parser vs hand-countable references."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo_text, parse_hlo, aggregate


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    out = analyze_hlo_text(_hlo(lambda x, y: x @ y, a, b))
    assert out["flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_trip_count():
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ c, ()
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    out = analyze_hlo_text(_hlo(fn, a))
    # 7 iterations x one 16^3 matmul
    assert out["flops"] == 7 * 2 * 16 ** 3


def test_nested_scan_multiplies():
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, ()
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, ()
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    out = analyze_hlo_text(_hlo(fn, a))
    assert out["flops"] == 5 * 3 * 2 * 8 ** 3


def test_symbol_table_resolves_operand_shapes():
    """Optimized HLO prints operands as bare names; contraction sizes must
    come from the per-computation symbol table."""
    a = jax.ShapeDtypeStruct((4, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 8), jnp.float32)

    def fn(x, y):
        return (x * 2.0) @ (y + 1.0)

    out = analyze_hlo_text(_hlo(fn, a, b))
    assert out["flops"] == 2 * 4 * 256 * 8


def test_computation_headers_with_tuple_params():
    """While-loop bodies have tuple-typed params whose nested parens broke
    a regex-based header parser once; ops inside must still be found."""
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def fn(x):
        def body(carry, _):
            c, d = carry
            return (c @ c, d + 1), ()
        (c, d), _ = jax.lax.scan(body, (x, jnp.zeros(())), None, length=4)
        return c, d

    text = _hlo(fn, a)
    out = analyze_hlo_text(text)
    assert out["flops"] == 4 * 2 * 16 ** 3


def test_dus_aliasing_discount():
    """In-place cache updates must not count the full carried buffer."""
    cache = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)  # 4 MB
    row = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def fn(c, r):
        return jax.lax.dynamic_update_slice(c, r, (5, 0))

    out = analyze_hlo_text(_hlo(fn, cache, row))
    full = 1024 * 1024 * 4
    # the un-donated input is copied once on CPU (2*full); the DUS itself
    # must contribute ~0 -- without the aliasing discount this would be
    # >= 4*full (copy + DUS operand+result)
    assert out["bytes"] < 2.2 * full, out["bytes"]
