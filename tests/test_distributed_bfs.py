"""Distributed-BFS tests.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps seeing exactly 1 device (per the dry-run isolation rule).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.compat import make_mesh
from repro.core import bfs_oracle, partition_graph
from repro.core.bfs_distributed import DistConfig, DistributedBFS
from repro.graph import get_dataset

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_single_shard_mesh_matches_oracle():
    ds = get_dataset("tiny-16-4")
    pg = partition_graph(ds.csr, ds.csc, 1)
    mesh = make_mesh((1,), ("data",))
    eng = DistributedBFS(pg, mesh, cfg=DistConfig(dispatch="bitmap"))
    lev = eng.run(0)
    np.testing.assert_array_equal(lev, bfs_oracle(ds.csr, 0))


_SUBPROC = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.compat import make_mesh
    from repro.graph import get_dataset
    from repro.core import bfs_oracle, partition_graph
    from repro.core.bfs_distributed import DistributedBFS, DistConfig
    from repro.core.scheduler import SchedulerConfig

    ds = get_dataset("small-12-8")
    pg = partition_graph(ds.csr, ds.csc, 8)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    orc = bfs_oracle(ds.csr, 7)
    out = {}
    for dispatch, crossbar in [("bitmap", "staged"), ("bitmap", "flat"),
                               ("queue", "flat")]:
        cfg = DistConfig(dispatch=dispatch, crossbar=crossbar,
                         queue_capacity=256,
                         scheduler=SchedulerConfig(policy="beamer"))
        eng = DistributedBFS(pg, mesh, cfg=cfg)
        lev = eng.run(7)
        out[f"{dispatch}-{crossbar}"] = bool(np.array_equal(lev, orc))
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_multidevice_dispatch_modes():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT "):])
    assert all(res.values()), res


_SUBPROC_PES = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.compat import make_mesh
    from repro.graph import get_dataset
    from repro.core import bfs_oracle, partition_graph
    from repro.core.bfs_distributed import DistributedBFS, DistConfig

    ds = get_dataset("small-12-8")
    orc = bfs_oracle(ds.csr, 7)
    mesh = make_mesh((4, 2), ("data", "model"))
    out = {}
    # k PEs per PC (Fig. 10's scaling direction) x partition schemes
    for k in (1, 2, 4):
        for scheme in ("hash", "contiguous"):
            for dispatch in ("bitmap", "queue"):
                pg = partition_graph(ds.csr, ds.csc, 8 * k, scheme=scheme)
                eng = DistributedBFS(pg, mesh, cfg=DistConfig(
                    dispatch=dispatch, queue_capacity=512))
                lev = eng.run(7)
                out[f"k{k}-{scheme}-{dispatch}"] = bool(
                    np.array_equal(lev, orc))
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_multidevice_pes_per_pc_and_schemes():
    """k>1 shards (PEs) per device x hash/contiguous x dispatch engines."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC_PES], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT "):])
    assert all(res.values()), res
