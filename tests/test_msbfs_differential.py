"""Differential MS-BFS sweep: every engine path against every other.

Three independent implementations answer the same batch of queries —
``MultiSourceBFSRunner`` (hybrid gather pipeline, with and without the
Pallas P3 kernel), ``msbfs_reference`` (dense jit loop), and the
pure-python per-root ``bfs_oracle`` — and must agree bit-for-bit at batch
sizes that exercise partial plane words (1, 5, 31, 33, 48) on random
graphs that include isolated vertices and self-loops.

Also: oracle tests for ``DistributedBFS.run_batch`` under forced
push-only / pull-only scheduling (the hybrid path was the only one
exercised before), batches wider than one plane word, and the
``bfs_batch`` root-validation contract (ValueError out of range,
duplicates allowed).
"""
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import (BFSRunner, MultiSourceBFSRunner, SchedulerConfig,
                        bfs_oracle, build_local_graph, msbfs_reference,
                        partition_graph)
from repro.core.bfs_distributed import DistConfig, DistributedBFS
from repro.graph import csr_from_edges, transpose_csr

N = 128


def _awkward_graph(n: int, m: int, seed: int):
    """Random digraph with guaranteed isolated vertices and self-loops.

    Edges are confined to the first 3n/4 vertices (the last quarter is
    fully isolated: no in- or out-edges), and every 16th active vertex
    gets a self-loop.
    """
    rng = np.random.default_rng(seed)
    hi = (3 * n) // 4
    src = rng.integers(0, hi, m)
    dst = rng.integers(0, hi, m)
    loops = np.arange(0, hi, 16)
    src = np.concatenate([src, loops])
    dst = np.concatenate([dst, loops])
    csr = csr_from_edges(src, dst, n)
    assert (np.diff(csr.indptr)[hi:] == 0).all()      # isolates exist
    return csr, build_local_graph(csr, transpose_csr(csr))


def _roots(n: int, batch: int, seed: int) -> np.ndarray:
    """Batch of roots that always includes an isolated vertex and a
    self-loop vertex when it has room for them."""
    rng = np.random.default_rng(seed)
    roots = rng.choice(n, batch, replace=False)
    if batch >= 2:
        roots[0] = n - 1        # isolated (edges confined to [0, 3n/4))
        roots[1] = 16           # self-loop vertex
    return roots.astype(np.int32)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp-p3", "pallas-p3"])
@pytest.mark.parametrize("batch", [1, 5, 31, 33, 48])
def test_runner_vs_reference_vs_oracle(batch, use_pallas):
    csr, g = _awkward_graph(N, 512, seed=100 + batch)
    roots = _roots(N, batch, seed=batch)
    res = MultiSourceBFSRunner(g, use_pallas=use_pallas).run(roots)
    ref = np.asarray(msbfs_reference(g, roots))
    np.testing.assert_array_equal(res.levels, ref)
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(res.levels[i].astype(np.int64),
                                      bfs_oracle(csr, int(r)))
    assert res.batch == batch and res.levels.shape == (batch, N)


# ---------------------------------------------------------------------------
# packed-word pipeline vs the legacy bool-plane path (tentpole differential):
# the fused propagate (Pallas kernel AND the _scatter_or_rows/segment-scan
# jnp fallbacks) must agree bit-for-bit with the bool-plane implementation
# in BOTH directions, at batch sizes that exercise partial and multiple
# plane words, on graphs with isolates and self-loops.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp-propagate", "pallas-propagate"])
@pytest.mark.parametrize("policy", ["push", "pull", "beamer"])
@pytest.mark.parametrize("batch", [1, 32, 48])
def test_packed_vs_boolplane(batch, policy, use_pallas):
    csr, g = _awkward_graph(N, 512, seed=200 + batch)
    roots = _roots(N, batch, seed=7 * batch + 1)
    sched = SchedulerConfig(policy=policy)
    packed = MultiSourceBFSRunner(g, sched,
                                  use_pallas=use_pallas).run(roots)
    boolp = MultiSourceBFSRunner(g, sched, packed=False).run(roots)
    np.testing.assert_array_equal(packed.levels, boolp.levels)
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(packed.levels[i].astype(np.int64),
                                      bfs_oracle(csr, int(r)))
    assert packed.iterations == boolp.iterations
    if policy == "push":
        assert packed.pull_iters == 0 and packed.push_iters > 0
    if policy == "pull":
        assert packed.push_iters == 0 and packed.pull_iters > 0


def test_one_host_transfer_per_level():
    """Acceptance: the packed driver performs exactly ONE blocking
    device->host transfer per level — the fused int32[7] stats vector —
    plus one for the initial frontier stats and one final level readback
    (counted by the runner's ``_fetch`` wrapper)."""
    csr, g = _awkward_graph(N, 512, seed=9)
    roots = _roots(N, 32, seed=3)
    res = MultiSourceBFSRunner(g).run(roots)
    assert res.iterations > 1
    assert res.host_transfers == res.iterations + 2
    # the legacy bool-plane driver pays several blocking syncs per level
    legacy = MultiSourceBFSRunner(g, packed=False).run(roots)
    assert legacy.host_transfers >= 5 * legacy.iterations
    # single-source driver has the same one-sync structure
    r1 = BFSRunner(g).run(16)
    assert r1.host_transfers == r1.iterations + 2


def test_propagate_noninterpret_call_path():
    """Exercise the non-interpret kernel call path (compiles only on TPU)."""
    import jax
    from repro.kernels import ops as kops
    import jax.numpy as jnp
    if jax.default_backend() != "tpu":
        pytest.skip("non-interpret Pallas path needs a TPU backend")
    fw = jnp.asarray(np.random.default_rng(0).integers(
        0, 2**32, (64, 1), dtype=np.uint32))
    sw = jnp.zeros((64, 1), jnp.uint32)
    src = jnp.arange(64, dtype=jnp.int32)
    new, seen, cnt = kops.msbfs_propagate(fw, sw, src, src,
                                          jnp.ones(64, bool),
                                          interpret=False)
    assert new.shape == (64, 1)


def test_isolated_root_reaches_only_itself():
    csr, g = _awkward_graph(N, 512, seed=0)
    res = MultiSourceBFSRunner(g).run(np.asarray([N - 1], np.int32))
    assert res.levels[0][N - 1] == 0
    assert (res.levels[0] >= (1 << 30)).sum() == N - 1


def test_self_loop_does_not_change_levels():
    # same random edges, with and without an added self-loop at the root
    rng = np.random.default_rng(5)
    src, dst = rng.integers(0, 96, 400), rng.integers(0, 96, 400)
    csr_a = csr_from_edges(src, dst, N)
    csr_b = csr_from_edges(np.append(src, 7), np.append(dst, 7), N)
    roots = np.asarray([7, 20], np.int32)
    res_a = MultiSourceBFSRunner(
        build_local_graph(csr_a, transpose_csr(csr_a))).run(roots)
    res_b = MultiSourceBFSRunner(
        build_local_graph(csr_b, transpose_csr(csr_b))).run(roots)
    np.testing.assert_array_equal(res_a.levels, res_b.levels)


# ---------------------------------------------------------------------------
# distributed run_batch: forced directions + multi-word batches
# ---------------------------------------------------------------------------

def _dist_engine(policy: str = "beamer", shards: int = 4, seed: int = 3):
    rng = np.random.default_rng(seed)
    src, dst = rng.integers(0, 64, 256), rng.integers(0, 64, 256)
    csr = csr_from_edges(src, dst, 64)
    pg = partition_graph(csr, transpose_csr(csr), shards)
    mesh = make_mesh((1,), ("data",))
    cfg = DistConfig(scheduler=SchedulerConfig(policy=policy))
    return csr, DistributedBFS(pg, mesh, cfg=cfg)


@pytest.mark.parametrize("policy", ["push", "pull"])
def test_distributed_run_batch_forced_direction(policy):
    """Push-only and pull-only batched steps must match the oracle on
    their own (the hybrid path can mask a broken direction)."""
    csr, eng = _dist_engine(policy)
    roots = np.asarray([0, 2, 5, 31, 63])
    levels = eng.run_batch(roots)
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(levels[i], bfs_oracle(csr, int(r)))
    key = "pull_iters" if policy == "pull" else "push_iters"
    other = "push_iters" if policy == "pull" else "pull_iters"
    assert eng.last_stats[key] > 0 and eng.last_stats[other] == 0


def test_distributed_run_batch_wider_than_one_plane_word():
    """40 concurrent sources = 2 packed uint32 words per vertex."""
    csr, eng = _dist_engine("beamer")
    roots = np.random.default_rng(11).choice(64, 40, replace=False)
    levels = eng.run_batch(roots)
    assert levels.shape == (40, 64)
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(levels[i], bfs_oracle(csr, int(r)))


# ---------------------------------------------------------------------------
# bfs_batch root-validation contract
# ---------------------------------------------------------------------------

def test_bfs_batch_rejects_out_of_range_roots():
    from repro.launch.serve import bfs_batch, build_bfs_engine
    engine, deg = build_bfs_engine("tiny-16-4", distributed=False)
    for bad in ([-1], [16], [3, -2, 5], [1 << 40]):
        with pytest.raises(ValueError, match="out of range"):
            bfs_batch(np.asarray(bad), engine=engine, out_deg=deg)
    with pytest.raises(ValueError):
        bfs_batch(np.asarray([], np.int64), engine=engine, out_deg=deg)


def test_bfs_batch_allows_duplicate_roots():
    from repro.launch.serve import bfs_batch, build_bfs_engine
    engine, deg = build_bfs_engine("tiny-16-4", distributed=False)
    out = bfs_batch(np.asarray([3, 3, 9]), engine=engine, out_deg=deg)
    assert out["batch"] == 3
    np.testing.assert_array_equal(out["levels"][0], out["levels"][1])


def test_engine_run_validates_directly():
    csr, g = _awkward_graph(N, 256, seed=1)
    with pytest.raises(ValueError):
        MultiSourceBFSRunner(g).run(np.asarray([0, N], np.int32))
    # a >= 2**31 root must error, not wrap through the int32 cast
    with pytest.raises(ValueError):
        MultiSourceBFSRunner(g).run(np.asarray([2 ** 32 + 5], np.int64))
    # float roots must error, not truncate
    with pytest.raises(ValueError, match="integers"):
        MultiSourceBFSRunner(g).run(np.asarray([5.7]))
    csr2, eng = _dist_engine()
    with pytest.raises(ValueError):
        eng.run_batch(np.asarray([-3]))
    with pytest.raises(ValueError):
        eng.run_batch(np.asarray([[1, 2]]))
