"""Worker pool serving (``repro.launch.pool``).

A pool of independent MS-BFS runners (sharing one device-resident
graph) behind a single submit surface: join-shortest-queue routing with
a round-robin tiebreak, QueueFull failover, merged stats with pooled
latency percentiles, SLO passthrough, and per-worker supervision.
Fake-clock pools are deterministic (no threads); one threaded pipelined
test covers the real-clock path.
"""
import numpy as np
import pytest

from repro.core import MultiSourceBFSRunner, bfs_oracle, build_local_graph
from repro.graph import csr_from_edges, transpose_csr, uniform_edges
from repro.launch.dynbatch import BatcherClosed, Overloaded, QueueFull
from repro.launch.pool import EVICTED, HEALTHY, SUSPECT, WorkerPool


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += float(dt)


@pytest.fixture(scope="module")
def graph():
    src, dst = uniform_edges(256, 1024, seed=7)
    csr = csr_from_edges(src, dst, 256)
    return csr, build_local_graph(csr, transpose_csr(csr))


@pytest.fixture()
def engines(graph):
    # independent runners over ONE device-resident graph
    return [MultiSourceBFSRunner(graph[1]) for _ in range(2)]


def test_pool_needs_at_least_one_engine():
    with pytest.raises(ValueError):
        WorkerPool([])


def test_pool_spreads_requests_and_matches_oracle(graph, engines):
    """JSQ + round-robin routing: 8 back-to-back submits land 4/4 across
    2 idle workers, and every future matches its per-root oracle."""
    csr, _ = graph
    deg = np.asarray(engines[0].out_deg)
    pool = WorkerPool(engines, out_deg=deg, window=1.0, max_batch=32,
                      clock=FakeClock())
    roots = [2, 50, 100, 150, 200, 250, 33, 77]
    futures = [pool.submit(r, block=False) for r in roots]
    assert pool.backlog() == len(roots)
    waves = pool.flush()
    assert len(waves) == 2                  # one wave per worker
    assert pool.backlog() == 0
    for f, r in zip(futures, roots):
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      bfs_oracle(csr, r))
    s = pool.stats()
    assert s["workers"] == 2 and s["waves"] == 2
    assert s["requests"] == len(roots)
    assert [p["requests"] for p in s["per_worker"]] == [4, 4]
    assert s["traversed_edges"] == sum(
        p["traversed_edges"] for p in s["per_worker"])
    assert s["latency_p99"] >= s["latency_p50"] >= 0
    pool.close()


def test_pool_routes_to_least_backlogged_worker(graph, engines):
    """A busy worker stops receiving: queue 3 on the pool, flush only
    worker 0's wave, then new submits must prefer the drained worker."""
    pool = WorkerPool(engines, window=1.0, clock=FakeClock())
    pool.submit(1, block=False)             # worker A (round-robin)
    pool.submit(2, block=False)             # worker B
    pool.submit(3, block=False)             # tie again -> A (or B): 2/1
    loads = sorted(w.backlog() for w in pool.workers)
    assert loads == [1, 2]
    light = min(pool.workers, key=lambda w: w.backlog())
    pool.submit(4, block=False)             # JSQ: must go to the light one
    assert light.backlog() == 2
    pool.flush()
    pool.close()


def test_pool_queuefull_failover_and_exhaustion(graph, engines):
    """Non-blocking submit fails over to the other worker's queue and
    only raises once EVERY queue is full."""
    pool = WorkerPool(engines, window=1.0, max_pending=1,
                      clock=FakeClock())
    pool.submit(1, block=False)             # fills worker A
    pool.submit(2, block=False)             # fails over to worker B
    with pytest.raises(QueueFull):
        pool.submit(3, block=False)         # both full
    pool.flush()
    pool.submit(3, block=False)             # capacity freed
    pool.close(drain=True)


def test_pool_slo_accounting_merges(graph, engines):
    csr, _ = graph
    clock = FakeClock()
    pool = WorkerPool(engines, window=0.1, clock=clock, slo_margin=0.0)
    f_ok = pool.submit(5, block=False, deadline=10.0)
    f_late = pool.submit(7, block=False, deadline=0.5)
    clock.advance(1.0)                      # f_late's deadline blown
    pool.flush()
    assert f_ok.slo_miss is False and f_late.slo_miss is True
    s = pool.stats()
    assert s["slo_requests"] == 2 and s["slo_misses"] == 1
    assert s["slo_miss_rate"] == 0.5
    np.testing.assert_array_equal(np.asarray(f_late.result(), np.int64),
                                  bfs_oracle(csr, 7))
    pool.close()


def test_pool_close_closes_every_worker(graph, engines):
    pool = WorkerPool(engines, window=1.0, clock=FakeClock())
    f = pool.submit(9, block=False)
    pool.close(drain=True)                  # drains despite open window
    assert f.done() and f.exception() is None
    for w in pool.workers:
        with pytest.raises(BatcherClosed):
            w.submit(1, block=False)


def test_pool_per_worker_supervision(graph, engines):
    """Each worker composes with its OWN EngineSupervisor: a poisoned
    root quarantines on whichever worker it landed on, clean requests on
    both workers serve correctly, and merged stats carry one
    fault_tolerance block per worker."""
    from repro.ft import EngineSupervisor, FaultyEngine, RequestQuarantined

    csr, _ = graph
    sups = [EngineSupervisor(FaultyEngine(e, poisoned_roots=[42]),
                             backoff=0.0, watchdog=False)
            for e in engines]
    deg = np.asarray(engines[0].out_deg)
    pool = WorkerPool(sups, out_deg=deg, window=1.0, clock=FakeClock())
    roots = [3, 42, 17, 99]
    futures = [pool.submit(r, block=False) for r in roots]
    pool.flush()
    for f, r in zip(futures, roots):
        if r == 42:
            assert isinstance(f.exception(), RequestQuarantined)
        else:
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=0), np.int64),
                bfs_oracle(csr, r))
    s = pool.stats()
    assert s["requests_failed"] == 1
    assert len(s["fault_tolerance"]) == 2
    assert sorted(q for ft in s["fault_tolerance"]
                  for q in ft["quarantined"]) == [42]
    pool.close()


def test_threaded_pipelined_pool_matches_oracle(graph, engines):
    """Real-clock pool with pipelined workers: the full production
    topology (pool -> per-worker cutter/dispatcher/finisher)."""
    csr, _ = graph
    roots = [2, 50, 100, 150, 200, 250]
    with WorkerPool(engines, window=0.02, max_batch=64,
                    pipeline=True) as pool:
        futures = [pool.submit(r) for r in roots]
        levels = [f.result(timeout=120.0) for f in futures]
    for lv, r in zip(levels, roots):
        np.testing.assert_array_equal(np.asarray(lv, np.int64),
                                      bfs_oracle(csr, r))
    s = pool.stats()
    assert s["pipeline"] is True and s["requests"] == len(roots)


# ---------------------------------------------------------------------------
# Health state machine: eviction, redispatch, probe re-admission, shedding
# ---------------------------------------------------------------------------

class DeadEngine:
    """BFSEngine-protocol double for a permanently dead worker."""

    num_vertices = 256

    def __init__(self):
        self.calls = 0

    def run_batch(self, roots, **kw):
        self.calls += 1
        raise RuntimeError("engine dead")


def test_pool_validates_health_thresholds(graph, engines):
    with pytest.raises(ValueError):
        WorkerPool(engines, evict_after=0)
    with pytest.raises(ValueError):
        WorkerPool(engines, evict_after=2, suspect_after=3)


def test_dead_worker_evicted_within_threshold_all_futures_resolve(graph):
    """Tentpole acceptance: a permanently dead engine is evicted after
    exactly ``evict_after`` failing waves, every queued and in-flight
    future is redispatched to the survivor, and all resolve correctly —
    zero hangs, zero request-level errors."""
    csr, g = graph
    dead = DeadEngine()
    engines = [dead, MultiSourceBFSRunner(g)]
    deg = np.asarray(engines[1].out_deg)
    pool = WorkerPool(engines, out_deg=deg, evict_after=2, window=1.0,
                      max_batch=2, clock=FakeClock())
    roots = [2, 50, 100, 150, 200, 250, 33, 77]
    futures = [pool.submit(r, block=False) for r in roots]
    pool.flush()                            # loops until redispatches quiesce
    assert all(f.done() for f in futures)
    for f, r in zip(futures, roots):
        assert f.exception() is None, f"root {r}: {f.exception()!r}"
        np.testing.assert_array_equal(np.asarray(f.result(), np.int64),
                                      bfs_oracle(csr, r))
    s = pool.stats()
    assert s["health"] == [EVICTED, HEALTHY]
    assert s["evictions"] == 1
    assert s["redispatches"] >= 4           # dead worker's share traveled
    assert "requests_failed" not in s
    # evicted exactly at the threshold: the dead engine saw evict_after
    # failing waves and not one more
    assert s["per_worker"][0]["errors"] == 2 and dead.calls == 2
    pool.close(drain=True)                  # evicted worker skips drain


def test_probe_readmits_with_replacement_engine(graph):
    csr, g = graph
    pool = WorkerPool([DeadEngine(), MultiSourceBFSRunner(g)],
                      evict_after=1, window=1.0, clock=FakeClock(),
                      engine_factory=lambda idx: MultiSourceBFSRunner(g))
    f = pool.workers[0].submit(7, block=False)
    pool.flush()
    assert pool.health() == [EVICTED, HEALTHY]
    assert f.exception() is None            # redispatched to the survivor
    assert pool.probe_evicted() == 1
    assert pool.health() == [HEALTHY, HEALTHY]
    f2 = pool.workers[0].submit(9, block=False)   # rebuilt worker serves
    pool.flush()
    np.testing.assert_array_equal(np.asarray(f2.result(), np.int64),
                                  bfs_oracle(csr, 9))
    s = pool.stats()
    assert s["probes"] == 1 and s["probe_failures"] == 0
    pool.close()


def test_probe_without_factory_keeps_dead_worker_evicted(graph):
    _, g = graph
    pool = WorkerPool([DeadEngine(), MultiSourceBFSRunner(g)],
                      evict_after=1, window=1.0, clock=FakeClock())
    pool.workers[0].submit(7, block=False)
    pool.flush()
    assert pool.probe_evicted() == 0        # dead engine fails its probe
    assert pool.health() == [EVICTED, HEALTHY]
    s = pool.stats()
    assert s["probes"] == 1 and s["probe_failures"] == 1
    pool.close()


def test_suspect_worker_ranked_last_then_recovers(graph, engines):
    """One failing wave marks a worker SUSPECT (ranked last for new
    work); its next successful wave re-admits it to HEALTHY."""
    from repro.ft import FaultPlan, FaultyEngine

    _, g = graph
    flaky = FaultyEngine(engines[0], FaultPlan([(0, "kernel")]))
    pool = WorkerPool([flaky, engines[1]], evict_after=3, suspect_after=1,
                      window=1.0, clock=FakeClock())
    f = pool.workers[0].submit(5, block=False)
    pool.flush()
    assert pool.health() == [SUSPECT, HEALTHY]
    assert f.exception() is None            # redispatched to worker 1
    pool.submit(11, block=False)            # routing shuns the suspect
    assert pool.workers[0].backlog() == 0
    assert pool.workers[1].backlog() == 1
    pool.flush()
    f2 = pool.workers[0].submit(13, block=False)  # fault plan exhausted
    pool.flush()
    assert f2.exception() is None
    assert pool.health() == [HEALTHY, HEALTHY]
    pool.close()


def test_pool_shed_rejects_doomed_deadline_typed(graph, engines):
    """Pool-level admission control: when even the best worker's
    estimated queue delay exceeds the deadline, submit raises a typed
    Overloaded instead of queueing a guaranteed SLO miss."""
    pool = WorkerPool(engines, shed=True, window=1.0, clock=FakeClock(),
                      service_hint=1.0)
    ok = pool.submit(3, block=False, deadline=10.0)     # admissible
    with pytest.raises(Overloaded):
        pool.submit(5, block=False, deadline=0.25)      # est 1.0s > 0.25s
    pool.submit(7, block=False)             # no deadline: never shed
    pool.flush()
    assert ok.exception() is None
    assert pool.stats()["shed"] == 1
    pool.close()


def test_all_workers_evicted_raises_overloaded(graph):
    pool = WorkerPool([DeadEngine()], evict_after=1, window=1.0,
                      clock=FakeClock())
    f = pool.submit(3, block=False)
    pool.flush()
    # no survivor to absorb the future: it fails typed, never hangs
    assert isinstance(f.exception(), RuntimeError)
    assert pool.health() == [EVICTED]
    with pytest.raises(Overloaded, match="evicted"):
        pool.submit(5, block=False)         # inline probe fails, refuse
    s = pool.stats()
    assert s["probes"] == 1 and s["probe_failures"] == 1
    pool.close()


def test_close_drain_never_redispatches_onto_closing_workers(graph):
    """Shutdown-vs-eviction ordering: the pool marks itself closed FIRST,
    so a worker failing during its drain fails its futures with the real
    engine error instead of requeueing them onto workers that are closing
    (or already closed) underneath it."""
    _, g = graph
    pool = WorkerPool([MultiSourceBFSRunner(g), DeadEngine()],
                      evict_after=2, window=1.0, clock=FakeClock())
    ok = pool.workers[0].submit(3, block=False)
    doomed = [pool.workers[1].submit(r, block=False) for r in (5, 9)]
    pool.close(drain=True)                  # worker 0 closes before 1 fails
    assert ok.done() and ok.exception() is None
    for f in doomed:
        assert f.done()
        assert isinstance(f.exception(), RuntimeError)
        assert not isinstance(f.exception(), BatcherClosed)
    assert "redispatches" not in pool.stats()
