"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs.  Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.models import (init_decode_state, init_params, loss_fn,
                          serve_step)
from repro.models.config import layer_plan_kinds


def _batch_for(cfg, B=2, S=16, enc_len=8):
    batch = {"labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["embeds"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.bfloat16)
    elif cfg.frontend == "audio_stub":
        batch["tokens"] = jnp.zeros((B, S), jnp.int32)
        batch["frames"] = jnp.full((B, enc_len, cfg.d_model), 0.01,
                                   jnp.bfloat16)
    else:
        batch["tokens"] = jnp.zeros((B, S), jnp.int32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_train_step(name):
    cfg = get_reduced_config(name)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch_for(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: loss_fn(p, cfg, b),
                           has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), name
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_serve_step(name):
    cfg = get_reduced_config(name)
    params = init_params(cfg, jax.random.key(0))
    B = 2
    caches = init_decode_state(cfg, B, 32, enc_len=8)
    logits, caches2 = jax.jit(
        lambda p, c, t, pos: serve_step(p, cfg, c, t, pos))(
        params, caches, jnp.zeros((B,), jnp.int32), jnp.int32(1))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    """The full configs carry the exact assigned dimensions."""
    cfg = get_config(name)
    expect = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, (name, got, expect)


def test_layer_patterns():
    g = layer_plan_kinds(get_config("gemma3-4b"))
    assert len(g) == 34
    assert g.count("attn_global") == 5            # 5:1 local:global
    assert all(k == "attn_global" for i, k in enumerate(g) if i % 6 == 5)
    r = layer_plan_kinds(get_config("recurrentgemma-2b"))
    assert len(r) == 26
    assert r.count("attn_local") == 8             # 2 RG-LRU : 1 attn
    assert r.count("rglru") == 18
    w = layer_plan_kinds(get_config("whisper-small"))
    assert w.count("enc") == 12 and w.count("dec") == 12
    m = layer_plan_kinds(get_config("mamba2-370m"))
    assert set(m) == {"ssm"} and len(m) == 48


def test_moe_configs():
    q = get_config("qwen3-moe-30b-a3b")
    assert q.num_experts == 128 and q.top_k == 8 and q.head_dim == 128
    p = get_config("phi3.5-moe-42b-a6.6b")
    assert p.num_experts == 16 and p.top_k == 2


def test_param_counts_in_expected_range():
    """Sanity-check param_count against the advertised model sizes."""
    bounds = {
        "llama3-8b": (7e9, 9e9),
        "llama3.2-3b": (2.8e9, 4e9),
        "mamba2-370m": (3e8, 4.5e8),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "gemma3-4b": (3e9, 5.5e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "llava-next-34b": (32e9, 36e9),
        "whisper-small": (2e8, 3.5e8),
    }
    for name, (lo, hi) in bounds.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, f"{n:.3e}", lo, hi)
