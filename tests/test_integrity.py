"""Traversal integrity layer (``repro.ft.integrity`` + engine guards).

Three layers under test, cheapest to strongest:

* statvec protocol invariants — per-level discovery popcounts recorded by
  every integrity-enabled run must be positive-then-terminate with the
  cumulative total bounded by |V| x planes, across every vertex program
  (BFS/CC/SSSP), batch width (1 / one word / multi-word), and both
  compute paths (jnp and Pallas), without breaking the
  ``host_transfers == iterations + 2`` protocol;
* detection — a single injected plane-word or result-row bit flip must
  raise :class:`IntegrityError` (the engine's device residue / witness
  reduction, or the host row-bounds check);
* recovery — the supervisor classifies the violation as a kernel-class
  transient fault and the retried wave serves oracle-matching rows.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ConnectedComponentsRunner, IntegrityError,
                        MultiSourceBFSRunner, SSSPRunner, bfs_oracle,
                        build_local_graph)
from repro.core.bfs_local import INF
from repro.core.vertex_program import SV_CHECK, _witness_check
from repro.ft import EngineSupervisor, FaultPlan, FaultyEngine
from repro.ft.integrity import (INTEGRITY_MODES, IntegrityConfig,
                                check_level_rows, check_popcount_sequence)
from repro.ft.supervisor import TRANSIENT, classify_fault, is_kernel_fault
from repro.graph import csr_from_edges, transpose_csr, uniform_edges

N = 256
RUNNERS = {"bfs": MultiSourceBFSRunner, "cc": ConnectedComponentsRunner,
           "sssp": SSSPRunner}


@pytest.fixture(scope="module")
def graph():
    src, dst = uniform_edges(N, 1024, seed=7)
    csr = csr_from_edges(src, dst, N)
    return csr, build_local_graph(csr, transpose_csr(csr))


@pytest.fixture(scope="module")
def roots48(graph):
    deg = np.diff(graph[0].indptr)
    reachable = np.flatnonzero(deg > 0)
    return np.resize(reachable, 48).astype(np.int64)


def _far_vertex(csr, root: int) -> int:
    """A vertex the oracle puts at level >= 3 (or unreached) from
    ``root``: XOR-ing its plane bit at level 1 always PLANTS a spurious
    discovery, which the statvec residue must catch."""
    lv = bfs_oracle(csr, root)
    far = np.flatnonzero((lv >= 3) | (lv == INF))
    assert far.size, "graph too dense for a far vertex"
    return int(far[0])


# ---------------------------------------------------------------------------
# Statvec protocol invariants across the program x batch x path matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas"])
@pytest.mark.parametrize("batch", [1, 32, 48])
@pytest.mark.parametrize("algo", sorted(RUNNERS))
def test_popcount_protocol_invariants(graph, roots48, algo, batch,
                                      use_pallas):
    """Every integrity-enabled run's discovery popcounts are
    positive-then-terminate, non-negative, and bounded — and the one
    extra statvec slot costs no extra device->host sync."""
    runner = RUNNERS[algo](graph[1], use_pallas=use_pallas,
                           integrity="invariants")
    res = runner.run(roots48[:batch])
    pcs = runner.last_stats["discovery_popcounts"]
    check_popcount_sequence(pcs)            # must not raise
    assert all(p >= 0 for p in pcs)
    if len(pcs) > 1:
        assert pcs[-1] == 0                 # frontier drained
        assert all(p > 0 for p in pcs[:-1])
    # cumulative discoveries are monotone and bounded by |V| x planes
    cum = np.cumsum(pcs)
    assert np.all(np.diff(cum) >= 0)
    assert cum[-1] <= N * batch
    assert runner.last_stats["integrity"]["sv_checks"] == len(pcs)
    # the residue slot rides the fused statvec: same sync count as off
    assert res.host_transfers == res.iterations + 2


def test_witness_mode_keeps_protocol_and_reports(graph, roots48):
    """Witness reduction rides the final fetch: no extra transfer, and
    the stats block reports the sample size (clipped to |V|)."""
    runner = MultiSourceBFSRunner(graph[1], integrity="witness",
                                  witness_k=4 * N)
    res = runner.run(roots48[:32])
    st = runner.last_stats["integrity"]
    assert st["mode"] == "witness"
    assert st["witness_sampled"] == N       # clipped to |V|
    assert st["witness_truncated"] is False
    assert res.host_transfers == res.iterations + 2


# ---------------------------------------------------------------------------
# Detection: injected single-bit corruption raises IntegrityError
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["invariants", "witness"])
def test_plane_flip_detected_by_device_residue(graph, roots48, mode):
    csr, g = graph
    runner = MultiSourceBFSRunner(g, integrity=mode)
    roots = roots48[:32]
    runner._corrupt_plane = (1, _far_vertex(csr, int(roots[0])), 0)
    with pytest.raises(IntegrityError):
        runner.run(roots)
    assert runner._corrupt_plane is None    # exact-once: hook consumed


def test_result_flip_detected_by_host_row_bounds(graph, roots48):
    """Bit 16 lands every level (and INF) outside [0, iterations]."""
    csr, g = graph
    runner = MultiSourceBFSRunner(g)
    roots = roots48[:32]
    res = runner.run(roots)
    rows = np.array(res.levels)
    v = _far_vertex(csr, int(roots[0]))
    rows[0, v] ^= np.int32(1 << 16)
    with pytest.raises(IntegrityError):
        check_level_rows(rows, roots, iterations=res.iterations)


def test_witness_reduction_flags_parentless_discovery(graph, roots48):
    """A vertex whose claimed level has no in-neighbor one level closer
    is exactly what the fused witness predicate counts."""
    csr, g = graph
    roots = roots48[:4]
    runner = MultiSourceBFSRunner(g)
    value = jnp.asarray(np.array(runner.run(roots).levels).T)  # [n, B]
    w = _far_vertex(csr, int(roots[0]))
    sample = jnp.asarray([w], jnp.int32)
    viol, trunc = (int(x) for x in
                   _witness_check(g, value, sample, budget=4096))
    assert viol == 0 and trunc == 0         # clean value rows pass
    bad = value.at[w, 0].set(1)             # claims level 1, no parent at 0
    viol, trunc = (int(x) for x in
                   _witness_check(g, bad, sample, budget=4096))
    assert viol >= 1 and trunc == 0


# ---------------------------------------------------------------------------
# Host-side check units
# ---------------------------------------------------------------------------

def test_check_level_rows_accepts_clean_and_rejects_corruption():
    rows = np.asarray([[0, 1, 2, INF], [1, 0, INF, 2]], np.int32)
    roots = np.asarray([0, 1])
    check_level_rows(rows, roots, iterations=2)
    bad = rows.copy()
    bad[1, 3] = 7                           # outside [0, iterations]
    with pytest.raises(IntegrityError, match="outside"):
        check_level_rows(bad, roots, iterations=2)
    lost = rows.copy()
    lost[0, 0] = 3                          # plane 0 lost its own root
    with pytest.raises(IntegrityError, match="lost its root"):
        check_level_rows(lost, roots, iterations=3)
    with pytest.raises(IntegrityError):
        check_level_rows(rows - 1, roots)   # negative values, no bound


@pytest.mark.parametrize("pcs,msg", [
    ([], "empty"),
    ([3, -1, 0], "negative"),
    ([0, 2, 0], "roots must seed"),
    ([4, 0, 3, 0], "hit 0 at level 1"),
    ([4, 2], "not drained"),
])
def test_check_popcount_sequence_rejects(pcs, msg):
    with pytest.raises(IntegrityError, match=msg):
        check_popcount_sequence(pcs)


def test_check_popcount_sequence_accepts():
    check_popcount_sequence([32])           # single-level (all roots leaf)
    check_popcount_sequence([32, 100, 7, 0])


def test_integrity_config_validation():
    assert IntegrityConfig().mode in INTEGRITY_MODES
    with pytest.raises(ValueError):
        IntegrityConfig(mode="paranoid")
    with pytest.raises(ValueError):
        IntegrityConfig(audit_rate=1.5)
    cfg = IntegrityConfig(mode="audit", audit_rate=0.5)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.mode = "off"


def test_integrity_error_is_kernel_class_transient():
    """Violations ride the retry + pallas->jnp->bool-plane ladder: they
    must classify transient (retryable) AND kernel-shaped (demotable)."""
    err = IntegrityError("corrupt frontier word")
    assert classify_fault(err) == TRANSIENT
    assert is_kernel_fault(err)


# ---------------------------------------------------------------------------
# Recovery: supervisor retries flipped waves to oracle-matching rows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["plane_flip", "result_flip"])
def test_supervisor_detects_and_recovers_bit_flip(graph, roots48, kind):
    csr, g = graph
    roots = roots48[:32]
    far = _far_vertex(csr, int(roots[0]))
    spec = {"plane_flip": dict(plane_flip=(1, far, 0)),
            "result_flip": dict(result_flip=(0, far, 16))}[kind]
    runner = MultiSourceBFSRunner(g)
    chaos = FaultyEngine(runner, FaultPlan([(0, kind)]), **spec)
    sup = EngineSupervisor(chaos, watchdog=False, backoff=0.0,
                           integrity=IntegrityConfig(mode="witness"))
    try:
        wave = sup.run_wave(roots)
    finally:
        runner.integrity = "off"            # knobs pushed onto the runner
    assert len(chaos.flips) == 1 and chaos.flips[0]["kind"] == kind
    assert wave.n_failed == 0               # detected, retried, recovered
    st = sup.stats()["integrity"]
    assert st["violations"] >= 1 and st["checks"] >= 1
    assert sup.stats()["retries"] >= 1
    for o in wave.outcomes:
        np.testing.assert_array_equal(np.asarray(o.levels, np.int64),
                                      bfs_oracle(csr, o.root))


def test_audit_tier_samples_clean_waves(graph, roots48):
    """audit_rate=1.0 re-runs every clean wave through the reference
    path; a clean engine must audit clean (zero false positives)."""
    runner = MultiSourceBFSRunner(graph[1])
    sup = EngineSupervisor(runner, watchdog=False, backoff=0.0,
                           integrity=IntegrityConfig(mode="audit",
                                                     audit_rate=1.0))
    try:
        wave = sup.run_wave(roots48[:32])
    finally:
        runner.integrity = "off"
    assert wave.n_failed == 0
    st = sup.stats()["integrity"]
    assert st["audits"] == 1 and st["audit_failures"] == 0
    assert st["violations"] == 0


def test_audit_rate_zero_never_audits(graph, roots48):
    runner = MultiSourceBFSRunner(graph[1])
    sup = EngineSupervisor(runner, watchdog=False, backoff=0.0,
                           integrity=IntegrityConfig(mode="audit",
                                                     audit_rate=0.0))
    try:
        for _ in range(3):
            assert sup.run_wave(roots48[:32]).n_failed == 0
    finally:
        runner.integrity = "off"
    assert sup.stats()["integrity"]["audits"] == 0
