"""Paper Fig. 8: push-only vs pull-only vs hybrid GTEPS.

Runs the local BFS engine (bfs_local.BFSRunner) on the paper's RMAT18
suite on CPU.  Absolute GTEPS are CPU numbers; the paper-claim validation
is the ORDERING and RATIO BANDS: hybrid >= push (1.2-2.1x in the paper)
and hybrid >> pull (3.65-11.52x), growing with graph density.
"""
from __future__ import annotations

import numpy as np

from repro.core import BFSRunner, SchedulerConfig, build_local_graph, bfs_oracle
from repro.graph import get_dataset

GRAPHS = ("rmat18-8", "rmat18-16", "rmat18-32", "rmat18-64")
POLICIES = ("push", "pull", "beamer")


def _best_root(csr) -> int:
    deg = np.diff(csr.indptr)
    return int(np.argmax(deg))


def run(graphs=GRAPHS, repeats: int = 2) -> dict:
    rows = []
    for name in graphs:
        ds = get_dataset(name)
        g = build_local_graph(ds.csr, ds.csc)
        root = _best_root(ds.csr)
        oracle = bfs_oracle(ds.csr, root)
        per_policy = {}
        for policy in POLICIES:
            runner = BFSRunner(g, SchedulerConfig(policy=policy))
            best = None
            for _ in range(repeats):
                res = runner.run(root)
                if best is None or res.seconds < best.seconds:
                    best = res
            assert np.array_equal(
                np.minimum(best.level, 1 << 30),
                np.minimum(oracle, 1 << 30)), (name, policy)
            per_policy[policy] = best
        h, pu, pl = (per_policy["beamer"], per_policy["push"],
                     per_policy["pull"])
        rows.append({
            "graph": name,
            "push_gteps": round(pu.gteps, 4),
            "pull_gteps": round(pl.gteps, 4),
            "hybrid_gteps": round(h.gteps, 4),
            "hybrid_over_push": round(h.gteps / max(pu.gteps, 1e-12), 2),
            "hybrid_over_pull": round(h.gteps / max(pl.gteps, 1e-12), 2),
            "hybrid_inspected": h.edges_inspected,
            "push_inspected": pu.edges_inspected,
            "pull_inspected": pl.edges_inspected,
            "hybrid_iters": f"{h.push_iters}p/{h.pull_iters}l",
        })
    return {"rows": rows, "paper_bands": {
        "hybrid_over_push": [1.20, 2.10], "hybrid_over_pull": [3.65, 11.52]}}
