"""Shared benchmark plumbing: result records, CSV printing, subprocess
runners for multi-device cases (the main process keeps 1 host device)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")
SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def save(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def print_rows(name: str, rows: list[dict]):
    if not rows:
        print(f"# {name}: (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"# {name}")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))


def run_subprocess(code: str, devices: int = 8, timeout: float = 1200.0):
    """Run python code with N forced host devices; expects a final JSON line."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import json
        {textwrap.indent(textwrap.dedent(code), '        ').lstrip()}
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    p = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    if p.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{p.stderr[-2000:]}")
    return json.loads(p.stdout.strip().splitlines()[-1])


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
