"""Paper Table III: BFS throughput on real-world graphs.

The container is offline, so the four real-world graphs are deterministic
RMAT stand-ins matched in directedness and average degree (graph/datasets
registry).  CPU GTEPS are reported next to the paper's U280 and
Gunrock/V100 numbers, and the §V model projects our engine onto the v5e
target at 32 chips for a like-for-like "what the port should reach".
"""
from __future__ import annotations

import numpy as np

from repro.core import BFSRunner, SchedulerConfig, build_local_graph
from repro.core.perf_model import tpu_model_teps
from repro.graph import get_dataset

PAPER = {
    # graph: (ScalaBFS U280 GTEPS, Gunrock V100 GTEPS, avg degree)
    "pk-like": (16.2, 14.9, 18.75),
    "lj-like": (11.2, 18.5, 14.23),
    "or-like": (19.1, 150.6, 76.28),
    "ho-like": (16.4, 73.0, 99.91),
}


def run(repeats: int = 2) -> dict:
    rows = []
    for name, (u280, v100, paper_deg) in PAPER.items():
        ds = get_dataset(name)
        g = build_local_graph(ds.csr, ds.csc)
        deg = np.diff(ds.csr.indptr)
        root = int(np.argmax(deg))
        runner = BFSRunner(g, SchedulerConfig(policy="beamer"))
        best = None
        for _ in range(repeats):
            res = runner.run(root)
            if best is None or res.seconds < best.seconds:
                best = res
        len_nl = float(deg[deg > 0].mean())
        rows.append({
            "graph": name,
            "cpu_gteps": round(best.gteps, 4),
            "iters": best.iterations,
            "push/pull": f"{best.push_iters}/{best.pull_iters}",
            "model_v5e32_gteps": round(tpu_model_teps(32, len_nl) / 1e9, 1),
            "paper_u280_gteps": u280,
            "paper_v100_gteps": v100,
        })
    return {"rows": rows,
            "note": "cpu_gteps is a 1-core CPU measurement; "
                    "model_v5e32_gteps is the §V analytic projection"}
