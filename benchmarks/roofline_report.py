"""§Roofline report: aggregate the dry-run sweep JSONs into the per-cell
three-term table (EXPERIMENTS.md reads from this)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "experiments/dryrun")


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(dryrun_dir: str = DRYRUN_DIR) -> dict:
    rows, skipped, bfs_rows = [], [], []
    for rec in load_cells(dryrun_dir):
        name = f"{rec.get('arch')}|{rec.get('shape')}|{rec.get('mesh')}"
        if "skipped" in rec:
            skipped.append({"cell": name, "why": rec["skipped"]})
            continue
        if rec.get("kind") == "bfs":
            for phase in ("push", "pull"):
                p = rec.get(phase)
                if not p:
                    continue
                r = p["roofline"]
                bfs_rows.append({
                    "cell": f"{name}|{phase}",
                    "compute_ms": round(r["compute_s"] * 1e3, 4),
                    "memory_ms": round(r["memory_s"] * 1e3, 4),
                    "collective_ms": round(r["collective_s"] * 1e3, 4),
                    "dominant": r["dominant"],
                    "coll_bytes": int(p["per_device"]["collective_bytes"]),
                })
            continue
        r = rec.get("roofline")
        if not r:
            continue
        mem = rec.get("memory_analysis", {})
        fits = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)) / 1e9
        rows.append({
            "cell": name,
            "kind": rec["kind"],
            "compute_ms": round(r["compute_s"] * 1e3, 3),
            "memory_ms": round(r["memory_s"] * 1e3, 3),
            "collective_ms": round(r["collective_s"] * 1e3, 3),
            "dominant": r["dominant"],
            "useful_ratio": round(r["useful_ratio"], 3),
            "roofline_frac_pct": round(r["roofline_fraction"] * 100, 3),
            "hbm_gb": round(fits, 2),
            "compile_s": rec.get("compile_s"),
        })
    worst = sorted((r for r in rows if r["kind"] == "train"),
                   key=lambda r: r["roofline_frac_pct"])[:5]
    coll = sorted(rows, key=lambda r: -r["collective_ms"])[:5]
    return {"rows": rows, "bfs_rows": bfs_rows, "skipped": skipped,
            "worst_train_cells": [r["cell"] for r in worst],
            "most_collective_bound": [r["cell"] for r in coll]}
