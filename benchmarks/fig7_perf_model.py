"""Paper Fig. 7: theoretical single-PC performance vs #PEs (Eq. 1-6).

Pure model evaluation with the paper's own constants (S_v=32b, F=100MHz,
BW_MAX=13.27GB/s) — reproduces the published curves exactly, including the
16-PE break-point — plus the TPU-v5e re-parameterization used by the
roofline section.
"""
from __future__ import annotations

from repro.core.perf_model import (PerfModelConfig, break_point_pes,
                                   fig7_curves, full_crossbar_fifos,
                                   multilayer_crossbar_fifos, perf_total,
                                   tpu_model_teps)


def run() -> dict:
    pe_counts = (1, 2, 4, 8, 16, 32, 64, 128)
    curves = fig7_curves(pe_counts=pe_counts)
    rows = []
    for ln, vals in curves.items():
        rows.append({"len_nl": ln, **{f"pe{p}": round(v, 3)
                                      for p, v in zip(pe_counts, vals)}})
    bp = break_point_pes()
    # paper §IV-D resource math: 64x64 full vs 3-layer 4x4 crossbar
    fifos_full_64 = full_crossbar_fifos(64)
    fifos_3l_64 = multilayer_crossbar_fifos((4, 4, 4))
    fifos_full_16 = full_crossbar_fifos(16)
    fifos_2l_16 = multilayer_crossbar_fifos((4, 4))
    # paper peak config: 32 PC x (2 PE/PC), dense graph Len_nl=61
    peak_model = perf_total(2, 32, 61.18) / 1e9
    return {
        "rows": rows,
        "break_point_pes": bp,
        "crossbar_fifos": {
            "full_64x64": fifos_full_64, "threelayer_4x4x4": fifos_3l_64,
            "full_16x16": fifos_full_16, "twolayer_4x4": fifos_2l_16,
        },
        "paper_peak_config_model_gteps": round(peak_model, 2),
        "tpu_v5e_32chip_model_gteps": round(
            tpu_model_teps(32, 61.18) / 1e9, 1),
        "checks": {
            "break_point_is_16": bp == 16,
            "fifo_halving_64": fifos_3l_64 * 2 < fifos_full_64,
            "fifo_halving_16": fifos_2l_16 * 2 == fifos_full_16,
        },
    }
