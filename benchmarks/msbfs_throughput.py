"""MS-BFS query-engine throughput: aggregate TEPS vs concurrent batch size.

Mirrors the Fig. 9/10 scaling methodology, with the batch of concurrent BFS
queries as the scaling direction: the paper raises aggregate GTEPS by
keeping all 32 HBM pseudo-channels busy; here each extra source rides the
SAME CSR/CSC edge stream (one bit-plane per source, packed in uint32
words), so per-memory-pass useful work grows with the batch while per-
iteration edge traffic grows only with the union frontier.  The structural
claim validated on CPU is therefore monotonically increasing aggregate
TEPS from batch=1 to batch=32 (absolute numbers are CPU figures).

  PYTHONPATH=src python -m benchmarks.msbfs_throughput
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import print_rows, save
from repro.core import MultiSourceBFSRunner, SchedulerConfig, \
    build_local_graph
from repro.graph import get_dataset


def run(graph: str = "rmat16-16", batch_sizes=(1, 2, 4, 8, 16, 32),
        policy: str = "beamer", seed: int = 0, repeats: int = 3) -> dict:
    ds = get_dataset(graph)
    g = build_local_graph(ds.csr, ds.csc)
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(seed)
    # roots with non-empty out-lists so every query traverses real work
    roots_all = rng.choice(np.flatnonzero(deg > 0), max(batch_sizes),
                           replace=False).astype(np.int32)
    runner = MultiSourceBFSRunner(g, SchedulerConfig(policy=policy))
    rows = []
    for b in batch_sizes:
        roots = roots_all[:b]
        runner.run(roots)                       # warm-up / compile
        best = None
        for _ in range(repeats):
            res = runner.run(roots)
            if best is None or res.seconds < best.seconds:
                best = res
        rows.append(dict(
            batch=b, seconds=round(best.seconds, 4),
            aggregate_teps=round(best.aggregate_teps, 1),
            aggregate_gteps=round(best.gteps, 6),
            teps_per_query=round(best.aggregate_teps / b, 1),
            iterations=best.iterations,
            edges_inspected=best.edges_inspected,
            push_iters=best.push_iters, pull_iters=best.pull_iters))
    base = rows[0]["aggregate_teps"]
    for r in rows:
        r["speedup_vs_b1"] = round(r["aggregate_teps"] / max(base, 1e-9), 2)
    return {"graph": graph, "policy": policy, "rows": rows,
            "monotonic": all(rows[i]["aggregate_teps"]
                             <= rows[i + 1]["aggregate_teps"]
                             for i in range(len(rows) - 1))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat16-16")
    ap.add_argument("--policy", default="beamer")
    ap.add_argument("--batches", type=int, nargs="*",
                    default=[1, 2, 4, 8, 16, 32])
    args = ap.parse_args()
    out = run(graph=args.graph, batch_sizes=tuple(args.batches),
              policy=args.policy)
    save("msbfs_throughput", out)
    print_rows("msbfs_throughput", out["rows"])
    print(f"  monotonic aggregate TEPS: {out['monotonic']}")


if __name__ == "__main__":
    main()
