"""MS-BFS query-engine throughput: aggregate TEPS vs concurrent batch size.

Mirrors the Fig. 9/10 scaling methodology, with the batch of concurrent BFS
queries as the scaling direction: the paper raises aggregate GTEPS by
keeping all 32 HBM pseudo-channels busy; here each extra source rides the
SAME CSR/CSC edge stream (one bit-plane per source, packed in uint32
words), so per-memory-pass useful work grows with the batch while per-
iteration edge traffic grows only with the union frontier.  Two structural
claims are validated on CPU (absolute numbers are CPU figures):

* monotonically increasing aggregate TEPS from batch=1 to batch=32, and
* the packed-word pipeline (gather/scatter-OR of uint32 plane words +
  one-sync-per-level driver) beats the legacy bool-plane path
  (``MultiSourceBFSRunner(packed=False)``) — the software re-run of the
  paper's "stream whole bitmap words per memory beat" argument.

The same harness benches the other vertex programs riding the engine
(packed arm only — the bool-plane baseline is BFS-specific):

  PYTHONPATH=src python -m benchmarks.msbfs_throughput --algo cc \
      --out BENCH_msbfs_cc.json

  PYTHONPATH=src python -m benchmarks.msbfs_throughput
  PYTHONPATH=src python -m benchmarks.msbfs_throughput \
      --out BENCH_msbfs.json --check   # CI: fail if packed is slower

The ``--use-pallas`` flag routes the packed arm through the fused Pallas
propagate kernel; at rmat20 scale the plane-array footprint exceeds the
VMEM budget, so ``kernels.ops.propagate_plan`` auto-selects the
row-tiled variant (edge stream pre-bucketed by target tile):

  PYTHONPATH=src python -m benchmarks.msbfs_throughput \
      --graph rmat20-16 --use-pallas --batches 32 --repeats 1 \
      --out BENCH_msbfs_rmat20.json --check
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from benchmarks.common import print_rows, save
from repro.core import (ConnectedComponentsRunner, MultiSourceBFSRunner,
                        SSSPRunner, SchedulerConfig, build_local_graph,
                        get_program)
from repro.graph import get_dataset, symmetrize_csr


def run(graph: str = "rmat16-16", batch_sizes=(1, 2, 4, 8, 16, 32),
        policy: str = "beamer", seed: int = 0, repeats: int = 3,
        packed_modes=(True, False), algo: str = "bfs",
        use_pallas: bool = False, tile_rows: int | None = None) -> dict:
    program = get_program(algo)
    ds = get_dataset(graph)
    csr, csc = ds.csr, ds.csc
    if program.undirected:
        csr = symmetrize_csr(csr)
        csc = csr            # a symmetrized graph is its own transpose
    g = build_local_graph(csr, csc)
    deg = np.diff(csr.indptr)
    rng = np.random.default_rng(seed)
    # roots with non-empty out-lists so every query traverses real work
    roots_all = rng.choice(np.flatnonzero(deg > 0), max(batch_sizes),
                           replace=False).astype(np.int32)
    rows = []
    for packed in packed_modes:
        sched = SchedulerConfig(policy=policy)
        # Pallas propagate (auto whole-VMEM vs row-tiled) applies to the
        # packed engine only; the bool-plane baseline stays pure jnp.
        kw = dict(use_pallas=use_pallas and packed, tile_rows=tile_rows)
        if algo == "bfs":
            runner = MultiSourceBFSRunner(g, sched, packed=packed, **kw)
        else:
            assert packed, "bool-plane baseline exists for BFS only"
            cls = {"cc": ConnectedComponentsRunner, "sssp": SSSPRunner}[algo]
            runner = cls(g, sched=sched, **kw)
        for b in batch_sizes:
            roots = roots_all[:b]
            runner.run(roots)                   # warm-up / compile
            best = None
            for _ in range(repeats):
                res = runner.run(roots)
                if best is None or res.seconds < best.seconds:
                    best = res
            rows.append(dict(
                batch=b, packed=packed, algo=algo,
                seconds=round(best.seconds, 4),
                aggregate_teps=round(best.aggregate_teps, 1),
                aggregate_gteps=round(best.gteps, 6),
                teps_per_query=round(best.aggregate_teps / b, 1),
                iterations=best.iterations,
                edges_inspected=best.edges_inspected,
                push_iters=best.push_iters, pull_iters=best.pull_iters,
                host_transfers=best.host_transfers))
    packed_rows = [r for r in rows if r["packed"]]
    # within-arm batch scaling: each arm's rows vs ITS OWN batch-1 row
    base_by_arm = {}
    for r in rows:
        base_by_arm.setdefault(r["packed"], r["aggregate_teps"])
    for r in rows:
        r["speedup_vs_b1"] = round(
            r["aggregate_teps"] / max(base_by_arm[r["packed"]], 1e-9), 2)
    out = {"graph": graph, "policy": policy, "algo": algo,
           "use_pallas": bool(use_pallas), "tile_rows": tile_rows,
           "rows": rows,
           "monotonic": all(packed_rows[i]["aggregate_teps"]
                            <= packed_rows[i + 1]["aggregate_teps"]
                            for i in range(len(packed_rows) - 1))}
    speedups = packed_speedups(rows)
    if speedups:
        out["packed_speedup"] = speedups
    return out


def packed_speedups(rows) -> dict:
    """Per-batch aggregate-TEPS ratio packed / bool-plane."""
    by = {}
    for r in rows:
        by.setdefault(r["batch"], {})[bool(r["packed"])] = r
    return {str(b): round(m[True]["aggregate_teps"]
                          / max(m[False]["aggregate_teps"], 1e-9), 2)
            for b, m in sorted(by.items()) if True in m and False in m}


def bench_record(out: dict) -> dict:
    """Stable BENCH_msbfs.json schema: graph, batch, packed, aggregate
    TEPS per row, plus the packed/bool-plane speedup map."""
    return {
        "graph": out["graph"],
        "policy": out["policy"],
        "algo": out.get("algo", "bfs"),
        "use_pallas": out.get("use_pallas", False),
        "tile_rows": out.get("tile_rows"),
        "rows": [dict(graph=out["graph"], batch=r["batch"],
                      packed=bool(r["packed"]),
                      aggregate_teps=r["aggregate_teps"])
                 for r in out["rows"]],
        "packed_speedup": out.get("packed_speedup", {}),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat16-16")
    ap.add_argument("--algo", choices=("bfs", "cc", "sssp"), default="bfs",
                    help="vertex program to bench (cc/sssp run the packed "
                         "engine arm only)")
    ap.add_argument("--policy", default="beamer")
    ap.add_argument("--batches", type=int, nargs="*",
                    default=[1, 2, 4, 8, 16, 32])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--packed-only", action="store_true",
                    help="skip the legacy bool-plane baseline arm")
    ap.add_argument("--use-pallas", action="store_true",
                    help="run the packed arm through the Pallas propagate "
                         "kernel (auto-selects whole-VMEM vs row-tiled by "
                         "plane-array footprint; see kernels.ops."
                         "propagate_plan)")
    ap.add_argument("--tile-rows", type=int, default=None,
                    help="with --use-pallas: 0 forces the whole-VMEM "
                         "kernel, >0 forces row tiles of that many "
                         "vertices (default: auto)")
    ap.add_argument("--out", metavar="PATH",
                    help="also write the stable benchmark record "
                         "(e.g. BENCH_msbfs.json at the repo root)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the packed path is at least "
                         "as fast as the bool-plane path at every batch")
    args = ap.parse_args()
    if args.check and args.packed_only:
        ap.error("--check needs both arms; drop --packed-only")
    if args.algo != "bfs":
        if args.check:
            ap.error("--check compares against the bool-plane baseline, "
                     "which exists for --algo bfs only")
        modes = (True,)      # no bool-plane arm for cc/sssp
    else:
        modes = (True,) if args.packed_only else (True, False)
    out = run(graph=args.graph, batch_sizes=tuple(args.batches),
              policy=args.policy, repeats=args.repeats, packed_modes=modes,
              algo=args.algo, use_pallas=args.use_pallas,
              tile_rows=args.tile_rows)
    name = ("msbfs_throughput" if args.algo == "bfs"
            else f"msbfs_throughput_{args.algo}")
    save(name, out)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(bench_record(out), f, indent=2)
    print_rows("msbfs_throughput", out["rows"])
    print(f"  monotonic aggregate TEPS (packed): {out['monotonic']}")
    if out.get("packed_speedup"):
        print(f"  packed/bool-plane speedup: {out['packed_speedup']}")
    if args.check:
        speedup = out.get("packed_speedup", {})
        if not speedup:
            print("CHECK FAILED: no packed-vs-bool-plane pairs were "
                  "measured", file=sys.stderr)
            sys.exit(1)
        slow = {b: s for b, s in speedup.items() if s < 1.0}
        if slow:
            print(f"CHECK FAILED: packed path slower than bool-plane "
                  f"fallback at batches {slow}", file=sys.stderr)
            sys.exit(1)
        print("  check passed: packed >= bool-plane at every batch")


if __name__ == "__main__":
    main()
