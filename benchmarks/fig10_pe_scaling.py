"""Paper Fig. 10: performance vs #PEs within a fixed number of PCs.

Adaptation (DESIGN.md §2): with memory channels fixed at D devices,
adding PEs = assigning more graph shards per device (Q = k*D, k PEs per
PC): each extra shard is an extra consumer of the same channel, exactly
the paper's PG-internal parallelism.  The paper's break-point appears
when the fixed channel saturates; here the fixed single core saturates,
producing the same knee shape (absolute GTEPS are CPU numbers).
"""
from __future__ import annotations

from benchmarks.common import run_subprocess

CODE = """
import numpy as np, jax, json, time
from repro.compat import make_mesh
from repro.graph import get_dataset
from repro.core import partition_graph
from repro.core.bfs_distributed import DistributedBFS, DistConfig

D, Q = {devices}, {shards}
ds = get_dataset("{graph}")
pg = partition_graph(ds.csr, ds.csc, Q)
mesh = make_mesh((D,), ("data",))
# Q shards over D devices: leading shard axis splits Q/D per device
eng = DistributedBFS(pg, mesh, cfg=DistConfig(dispatch="bitmap",
                                              crossbar="flat"))
deg = np.diff(ds.csr.indptr)
root = int(np.argmax(deg))
eng.run(root)
t0 = time.perf_counter(); lev = eng.run(root); dt = time.perf_counter()-t0
trav = int(deg[lev < (1<<30)].sum())
print(json.dumps(dict(devices=D, shards=Q, pes_per_pc=Q//D,
    seconds=round(dt,3), gteps=round(trav/dt/1e9, 5),
    iters=eng.last_stats["iterations"])))
"""


def run(graphs=("rmat18-8", "rmat18-64"), devices: int = 4,
        pes=(1, 2, 4, 8)) -> dict:
    rows = []
    for graph in graphs:
        for k in pes:
            out = run_subprocess(
                CODE.format(devices=devices, shards=devices * k,
                            graph=graph), devices=devices)
            out["graph"] = graph
            rows.append(out)
    return {"rows": rows}
