"""Paper Fig. 11: hash-partitioned placement vs baseline placement.

ScalaBFS distributes edge data evenly over PCs via VID%Q hashing; the
baseline stores edges contiguously starting from PC0, so PGs do unbalanced
remote reads and the switch collapses.  The TPU analogue of "achieved
aggregated bandwidth" is (a) the per-device edge-work balance (a device
can only stream what its own HBM holds) and (b) wall time of the same
BFS under each placement on a multi-device mesh.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_subprocess
from repro.graph import get_dataset
from repro.core import partition_graph

CODE = """
import numpy as np, jax, json, time
from repro.compat import make_mesh
from repro.graph import get_dataset
from repro.core import bfs_oracle, partition_graph
from repro.core.bfs_distributed import DistributedBFS, DistConfig

N = {devices}
ds = get_dataset("{graph}")
deg = np.diff(ds.csr.indptr)
root = int(np.argmax(deg))
out = {{}}
for scheme in ("hash", "contiguous"):
    pg = partition_graph(ds.csr, ds.csc, N, scheme=scheme)
    mesh = make_mesh((N,), ("data",))
    eng = DistributedBFS(pg, mesh, cfg=DistConfig(dispatch="bitmap",
                                                  crossbar="flat"))
    lev = eng.run(root)
    ok = bool(np.array_equal(np.minimum(lev,1<<30),
        np.minimum(bfs_oracle(ds.csr, root),1<<30)))
    t0 = time.perf_counter(); eng.run(root); dt = time.perf_counter()-t0
    per = pg.out_indptr[:, -1].astype(float)
    out[scheme] = dict(ok=ok, seconds=round(dt,3),
        edges_max=float(per.max()), edges_mean=float(per.mean()),
        imbalance=round(float(per.max()/max(per.mean(),1e-9)),3))
print(json.dumps(out))
"""


def run(graphs=("rmat18-16", "lj-like"), devices: int = 8) -> dict:
    rows = []
    for graph in graphs:
        out = run_subprocess(CODE.format(devices=devices, graph=graph),
                             devices=devices)
        h, c = out["hash"], out["contiguous"]
        rows.append({
            "graph": graph, "devices": devices,
            "hash_imbalance": h["imbalance"],
            "contig_imbalance": c["imbalance"],
            "hash_seconds": h["seconds"],
            "contig_seconds": c["seconds"],
            "contig_over_hash_time": round(
                c["seconds"] / max(h["seconds"], 1e-9), 2),
            "ok": h["ok"] and c["ok"],
        })
    return {"rows": rows}
