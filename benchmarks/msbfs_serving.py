"""Serving-mode MS-BFS benchmark: dynamic batching vs a static batch.

The throughput story of the paper (and of GraphScale / the HBM benchmarking
work in PAPERS.md) is about SUSTAINED utilization, not peak kernel speed:
what matters for serving is whether a stream of independent single-root
queries can be coalesced into full MS-BFS waves.  This benchmark drives the
``launch.dynbatch`` scheduler with an open-loop Poisson load generator and
compares against the static pre-batched upper bound:

* ``static``  — the same total number of queries served as pre-packed
  batch-``max_batch`` waves (the `msbfs_throughput` operating point).
* ``dynamic`` — queries submitted one at a time at ``rate`` req/s through
  ``DynamicBatcher``; the scheduler cuts a wave when 32 requests are
  pending or the oldest has waited ``window`` seconds.  Reported latency
  (p50/p99) is submit -> future-resolved, so it includes queueing.

The structural claim: with an arrival rate high enough to fill waves, the
coalesced stream's aggregate TEPS over busy time lands within ~10% of the
static batch — dynamic batching recovers nearly all of the batch-32 win
for traffic that never arrives batched.

  PYTHONPATH=src python -m benchmarks.msbfs_serving
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import print_rows, save
from repro.core import (MultiSourceBFSRunner, SchedulerConfig,
                        build_local_graph, count_traversed_edges)
from repro.graph import get_dataset
from repro.launch.dynbatch import (DynamicBatcher, drive_open_loop,
                                   plane_wave_sizes)


def _percentiles(lats):
    lats = np.asarray(lats, np.float64)
    return dict(latency_mean=round(float(lats.mean()), 4),
                latency_p50=round(float(np.percentile(lats, 50)), 4),
                latency_p99=round(float(np.percentile(lats, 99)), 4))


def run(graph: str = "rmat16-16", requests: int = 96, rate: float = 256.0,
        window: float = 0.5, max_batch: int = 32, policy: str = "beamer",
        seed: int = 0) -> dict:
    ds = get_dataset(graph)
    g = build_local_graph(ds.csr, ds.csc)
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(seed)
    roots = rng.choice(np.flatnonzero(deg > 0), requests,
                       replace=True).astype(np.int64)
    runner = MultiSourceBFSRunner(g, SchedulerConfig(policy=policy))
    # warm-up / compile: the static waves run batch=max_batch shapes, the
    # dynamic waves run plane-word-padded shapes — warm them all
    runner.run(np.resize(roots, max_batch))
    for m in plane_wave_sizes(max_batch):
        if m != max_batch:
            runner.run(np.resize(roots, m))

    # -- static upper bound: pre-packed batch-`max_batch` waves ----------
    # the last wave is padded to max_batch like the batcher pads to plane
    # words, but latency and traversed-edge accounting cover only the
    # `real` queries, matching the dynamic side's bookkeeping
    static_lat, static_busy, static_traversed, static_waves = [], 0.0, 0, 0
    for lo in range(0, requests, max_batch):
        real = min(max_batch, requests - lo)
        wave = np.resize(roots[lo:lo + max_batch], max_batch)
        res = runner.run(wave)
        static_waves += 1
        static_busy += res.seconds
        static_traversed += count_traversed_edges(deg, res.levels[:real])
        # every query in a pre-packed batch waits the whole wave
        static_lat += [res.seconds] * real
    static = dict(mode="static", waves=static_waves,
                  mean_batch=round(requests / static_waves, 2),
                  busy_seconds=round(static_busy, 4),
                  aggregate_teps=round(static_traversed
                                       / max(static_busy, 1e-12), 1),
                  **_percentiles(static_lat))

    # -- dynamic: open-loop Poisson arrivals through the batcher ---------
    batcher = DynamicBatcher(runner, out_deg=deg, window=window,
                             max_batch=max_batch)
    t0 = time.monotonic()
    drive_open_loop(batcher, roots, rate=rate, rng=rng)
    wall = time.monotonic() - t0
    dyn_stats = batcher.stats()
    dynamic = dict(mode="dynamic", waves=dyn_stats["waves"],
                   mean_batch=dyn_stats["mean_batch"],
                   busy_seconds=dyn_stats["busy_seconds"],
                   aggregate_teps=dyn_stats["aggregate_teps"],
                   latency_mean=dyn_stats["latency_mean"],
                   latency_p50=dyn_stats["latency_p50"],
                   latency_p99=dyn_stats["latency_p99"])

    ratio = dynamic["aggregate_teps"] / max(static["aggregate_teps"], 1e-12)
    return {"graph": graph, "requests": requests, "rate": rate,
            "window": window, "max_batch": max_batch, "policy": policy,
            "wall_seconds": round(wall, 4),
            "rows": [static, dynamic],
            "teps_ratio_dynamic_vs_static": round(ratio, 4),
            "within_10pct": bool(ratio >= 0.9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat16-16")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--rate", type=float, default=256.0,
                    help="open-loop Poisson arrival rate, req/s")
    ap.add_argument("--window", type=float, default=0.5,
                    help="coalescing window, seconds")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--policy", default="beamer")
    args = ap.parse_args()
    out = run(graph=args.graph, requests=args.requests, rate=args.rate,
              window=args.window, max_batch=args.max_batch,
              policy=args.policy)
    save("msbfs_serving", out)
    print_rows("msbfs_serving", out["rows"])
    print(f"  dynamic/static aggregate TEPS: "
          f"{out['teps_ratio_dynamic_vs_static']} "
          f"(within 10%: {out['within_10pct']})")


if __name__ == "__main__":
    main()
