"""Serving-mode MS-BFS benchmark: dynamic batching vs a static batch,
plus a deterministic chaos arm exercising the fault-tolerant supervisor.

The throughput story of the paper (and of GraphScale / the HBM benchmarking
work in PAPERS.md) is about SUSTAINED utilization, not peak kernel speed:
what matters for serving is whether a stream of independent single-root
queries can be coalesced into full MS-BFS waves.  This benchmark drives the
``launch.dynbatch`` scheduler with an open-loop Poisson load generator and
compares against the static pre-batched upper bound:

* ``static``  — the same total number of queries served as pre-packed
  batch-``max_batch`` waves (the `msbfs_throughput` operating point).
* ``dynamic`` — queries submitted one at a time at ``rate`` req/s through
  ``DynamicBatcher``; the scheduler cuts a wave when 32 requests are
  pending or the oldest has waited ``window`` seconds.  Reported latency
  (p50/p99) is submit -> future-resolved, so it includes queueing.

The structural claim: with an arrival rate high enough to fill waves, the
coalesced stream's aggregate TEPS over busy time lands within ~10% of the
static batch — dynamic batching recovers nearly all of the batch-32 win
for traffic that never arrives batched.

  PYTHONPATH=src python -m benchmarks.msbfs_serving

The ``--chaos`` arm replays the same stream through the fault-tolerant
stack (``repro.ft.EngineSupervisor`` over a ``FaultyEngine`` injecting a
deterministic ~``--fault-rate`` mix of kernel/runtime faults, one stuck
wave tripping the watchdog, and one poisoned root isolated by bisection)
and checks that EVERY request still resolves — with correct levels or a
typed error — and measures what the fault policy costs in latency/TEPS:

  PYTHONPATH=src python -m benchmarks.msbfs_serving --chaos \
      --fault-rate 0.1 --out BENCH_msbfs_chaos.json --check
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import print_rows, save
from repro.core import (MultiSourceBFSRunner, SchedulerConfig,
                        build_local_graph, count_traversed_edges)
from repro.graph import get_dataset
from repro.launch.dynbatch import (DynamicBatcher, drive_open_loop,
                                   plane_wave_sizes)


def _percentiles(lats):
    lats = np.asarray(lats, np.float64)
    return dict(latency_mean=round(float(lats.mean()), 4),
                latency_p50=round(float(np.percentile(lats, 50)), 4),
                latency_p99=round(float(np.percentile(lats, 99)), 4))


def run(graph: str = "rmat16-16", requests: int = 96, rate: float = 256.0,
        window: float = 0.5, max_batch: int = 32, policy: str = "beamer",
        seed: int = 0) -> dict:
    ds = get_dataset(graph)
    g = build_local_graph(ds.csr, ds.csc)
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(seed)
    roots = rng.choice(np.flatnonzero(deg > 0), requests,
                       replace=True).astype(np.int64)
    runner = MultiSourceBFSRunner(g, SchedulerConfig(policy=policy))
    # warm-up / compile: the static waves run batch=max_batch shapes, the
    # dynamic waves run plane-word-padded shapes — warm them all
    runner.run(np.resize(roots, max_batch))
    for m in plane_wave_sizes(max_batch):
        if m != max_batch:
            runner.run(np.resize(roots, m))

    # -- static upper bound: pre-packed batch-`max_batch` waves ----------
    # the last wave is padded to max_batch like the batcher pads to plane
    # words, but latency and traversed-edge accounting cover only the
    # `real` queries, matching the dynamic side's bookkeeping
    static_lat, static_busy, static_traversed, static_waves = [], 0.0, 0, 0
    for lo in range(0, requests, max_batch):
        real = min(max_batch, requests - lo)
        wave = np.resize(roots[lo:lo + max_batch], max_batch)
        res = runner.run(wave)
        static_waves += 1
        static_busy += res.seconds
        static_traversed += count_traversed_edges(deg, res.levels[:real])
        # every query in a pre-packed batch waits the whole wave
        static_lat += [res.seconds] * real
    static = dict(mode="static", waves=static_waves,
                  mean_batch=round(requests / static_waves, 2),
                  busy_seconds=round(static_busy, 4),
                  aggregate_teps=round(static_traversed
                                       / max(static_busy, 1e-12), 1),
                  **_percentiles(static_lat))

    # -- dynamic: open-loop Poisson arrivals through the batcher ---------
    batcher = DynamicBatcher(runner, out_deg=deg, window=window,
                             max_batch=max_batch)
    t0 = time.monotonic()
    drive_open_loop(batcher, roots, rate=rate, rng=rng)
    wall = time.monotonic() - t0
    dyn_stats = batcher.stats()
    dynamic = dict(mode="dynamic", waves=dyn_stats["waves"],
                   mean_batch=dyn_stats["mean_batch"],
                   busy_seconds=dyn_stats["busy_seconds"],
                   aggregate_teps=dyn_stats["aggregate_teps"],
                   latency_mean=dyn_stats["latency_mean"],
                   latency_p50=dyn_stats["latency_p50"],
                   latency_p99=dyn_stats["latency_p99"])

    ratio = dynamic["aggregate_teps"] / max(static["aggregate_teps"], 1e-12)
    return {"graph": graph, "requests": requests, "rate": rate,
            "window": window, "max_batch": max_batch, "policy": policy,
            "wall_seconds": round(wall, 4),
            "rows": [static, dynamic],
            "teps_ratio_dynamic_vs_static": round(ratio, 4),
            "within_10pct": bool(ratio >= 0.9)}


def run_chaos(graph: str = "rmat16-16", requests: int = 64,
              fault_rate: float = 0.1, rate: float = 256.0,
              window: float = 0.25, max_batch: int = 32,
              policy: str = "beamer", seed: int = 0,
              wave_deadline: float = 1.5,
              stall_seconds: float = 4.0) -> dict:
    """Drive the same open-loop stream through the supervised stack under
    deterministic fault injection; see the module docstring for the mix.

    Returns the fault-free dynamic arm (the existing within-10%-of-static
    gate) next to the chaos arm, plus the resolution/correctness record
    ``--check`` gates on: every future resolved, every non-poisoned
    request's levels equal to the fault-free reference, the poisoned root
    quarantined in <= ceil(log2 B)+1 faulted traversals, and a forced
    Pallas failure demoted to the jnp fallback with oracle-matching rows.
    """
    import math

    from repro.core import bitmap
    from repro.ft import (EngineSupervisor, FaultPlan, FaultyEngine,
                          RequestQuarantined)

    ds = get_dataset(graph)
    g = build_local_graph(ds.csr, ds.csc)
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(seed)
    roots = rng.choice(np.flatnonzero(deg > 0), requests,
                       replace=True).astype(np.int64)
    # one poisoned root, not colliding with any clean request
    poison_pool = np.setdiff1d(np.flatnonzero(deg > 0), roots)
    poison = int(poison_pool[rng.integers(poison_pool.size)])
    roots[rng.integers(requests)] = poison
    runner = MultiSourceBFSRunner(g, SchedulerConfig(policy=policy))
    for packed in (True, False):
        # warm the demotion ladder's landing rung too: a demoted wave must
        # not pay jit compilation inside its watchdog deadline
        runner.packed = packed
        for m in plane_wave_sizes(max_batch):
            runner.run(np.resize(roots, m))
    runner.packed = True

    # -- fault-free reference + static upper bound + fault-free dynamic --
    # shared hosts show ~10% slowdown noise in phases lasting seconds, so
    # the two sides of the within-10pct gate are measured INTERLEAVED
    # (static pass, dynamic pass, x3) and each takes its best pass — a
    # slow phase then degrades both arms instead of whichever it happened
    # to cover
    ref: dict[int, np.ndarray] = {}
    static_passes, free_passes = [], []

    def _arm(engine, *, raise_errors=True):
        batcher = DynamicBatcher(engine, out_deg=deg, window=window,
                                 max_batch=max_batch)
        futures = drive_open_loop(batcher, roots, rate=rate,
                                  rng=np.random.default_rng(seed + 1),
                                  raise_errors=raise_errors)
        return futures, batcher.stats()

    for _ in range(3):
        static_busy, static_traversed = 0.0, 0
        for lo in range(0, requests, max_batch):
            real = min(max_batch, requests - lo)
            wave = np.resize(roots[lo:lo + max_batch], max_batch)
            res = runner.run(wave)
            static_busy += res.seconds
            static_traversed += count_traversed_edges(deg,
                                                      res.levels[:real])
            for r, row in zip(wave[:real], res.levels[:real]):
                ref[int(r)] = np.asarray(row, np.int64).copy()
        static_passes.append(static_traversed / max(static_busy, 1e-12))
        free_passes.append(_arm(runner)[1])
    static_teps = round(float(np.max(static_passes)), 1)
    free = max(free_passes, key=lambda s: s["aggregate_teps"])
    # gate on the best SAME-PHASE pair: each dynamic pass is compared to
    # the static pass measured adjacent to it, so the 10% claim is about
    # scheduling overhead, not about which arm a host hiccup landed on
    pair_ratios = [f["aggregate_teps"] / max(s, 1e-12)
                   for s, f in zip(static_passes, free_passes)]
    ratio = float(np.max(pair_ratios))

    # -- chaos arm: plan-scheduled faults + poison + one stuck wave ------
    plan = FaultPlan.random(4 * (requests // max_batch + 2), fault_rate,
                            kinds=("kernel", "runtime"), seed=seed)
    faults = sorted(plan.pending().items())
    stuck_idx = next(i for i in range(1, 10_000)
                     if i not in plan.pending())
    faults.append((stuck_idx, "stuck"))
    chaos_engine = FaultyEngine(runner, FaultPlan(faults),
                                poisoned_roots=[poison],
                                stall_seconds=stall_seconds)
    supervisor = EngineSupervisor(chaos_engine, max_retries=3,
                                  backoff=0.01,
                                  wave_deadline=wave_deadline)
    futures, chaos = _arm(supervisor, raise_errors=False)

    resolved = sum(f.done() for f in futures)
    mismatched, failed_clean, quar_ok = [], [], 0
    for f, r in zip(futures, roots.tolist()):
        exc = f.exception()
        if exc is None:
            if not np.array_equal(np.asarray(f.result(), np.int64),
                                  ref[int(r)]):
                mismatched.append(int(r))
        elif int(r) == poison and isinstance(exc, RequestQuarantined):
            quar_ok += 1
        else:
            failed_clean.append(int(r))

    # -- bisection bound: poison alone in a clean full wave --------------
    bound = int(math.ceil(math.log2(max_batch))) + 1
    iso = EngineSupervisor(FaultyEngine(runner, poisoned_roots=[poison]),
                           watchdog=False, backoff=0.0)
    clean = np.asarray([r for r in np.unique(roots) if r != poison],
                       np.int64)
    iso_roots = np.resize(clean, max_batch)
    iso_roots[max_batch // 2] = poison
    iso_wave = iso.run_wave(iso_roots)

    # -- degradation ladder: forced Pallas failure -> jnp fallback -------
    prev_pallas = runner.use_pallas
    runner.use_pallas = True
    demo = EngineSupervisor(FaultyEngine(runner, break_pallas=True),
                            watchdog=False, backoff=0.0)
    demo_wave = demo.run_wave(clean[:max_batch])
    runner.use_pallas = prev_pallas
    demo_match = (demo_wave.n_failed == 0 and all(
        np.array_equal(np.asarray(o.levels, np.int64), ref[o.root])
        for o in demo_wave.outcomes))

    rows = [
        dict(mode="fault-free", waves=free["waves"],
             mean_batch=free["mean_batch"],
             busy_seconds=free["busy_seconds"],
             aggregate_teps=free["aggregate_teps"],
             latency_p50=free["latency_p50"],
             latency_p99=free["latency_p99"]),
        dict(mode="chaos", waves=chaos["waves"],
             mean_batch=chaos["mean_batch"],
             busy_seconds=chaos["busy_seconds"],
             aggregate_teps=chaos["aggregate_teps"],
             latency_p50=chaos["latency_p50"],
             latency_p99=chaos["latency_p99"]),
    ]
    return {
        "graph": graph, "requests": requests, "rate": rate,
        "window": window, "max_batch": max_batch, "policy": policy,
        "fault_rate": fault_rate, "poisoned_root": poison,
        "rows": rows,
        "static_teps": static_teps,
        "teps_ratio_dynamic_vs_static": round(ratio, 4),
        "within_10pct": bool(ratio >= 0.9),
        "chaos_teps_ratio_vs_fault_free": round(
            chaos["aggregate_teps"] / max(free["aggregate_teps"], 1e-12),
            4),
        "resolved": resolved,
        "resolution_rate": round(resolved / requests, 4),
        "mismatched_roots": mismatched,
        "failed_clean_roots": failed_clean,
        "poison_quarantined": bool(quar_ok),
        "fault_tolerance": chaos.get("fault_tolerance", {}),
        "injected": chaos_engine.plan.injected,
        "bisection": dict(fault_waves=iso_wave.fault_waves,
                          bound=bound,
                          within_bound=bool(iso_wave.fault_waves <= bound),
                          quarantined=iso_wave.quarantined,
                          clean_served=iso_wave.n_ok),
        "demotion": dict(demotions=demo_wave.demotions,
                         oracle_match=bool(demo_match)),
    }


def run_bitflip(graph: str = "rmat16-16", trials: int = 4,
                clean_waves: int = 4, burst_waves: int = 8,
                max_batch: int = 32, policy: str = "beamer", seed: int = 0,
                integrity: str = "witness",
                slo_factor: float = 3.0) -> dict:
    """Bit-flip chaos + integrity detection + overload shedding record.

    Three sub-experiments, all gated by ``check_bitflip``:

    * PLANE FLIPS — ``trials`` waves each corrupted by one exact-once XOR
      of a frontier plane word mid-traversal (a spurious discovery bit,
      the class the device-side statvec residue is built to catch).  Gate:
      every flip detected (an ``IntegrityError`` violation), every wave
      recovered by the supervisor's retry with reference-matching rows.
    * RESULT FLIPS — ``trials`` waves whose RETURNED rows get one bit-16
      XOR after the engine finished (value lands outside ``[0, iters]``,
      the class only the host row-bounds check can see).  Same gate.
    * CLEAN SWEEP — ``clean_waves`` uncorrupted waves through the same
      detector stack.  Gate: ZERO violations (no false positives).
    * OVERLOAD BURST — ``burst_waves x max_batch`` deadline requests
      submitted back-to-back (a ~``burst_waves/slo_factor``x overload for
      an SLO of ``slo_factor`` wave times) through a shedding and a
      non-shedding batcher.  Gate: the shedding arm's SERVED p99 beats
      the non-shedding arm's, and every reject returned in under one
      wave service time.
    """
    from repro.ft import (EngineSupervisor, FaultPlan, FaultyEngine,
                          IntegrityConfig)
    from repro.launch.dynbatch import Overloaded

    ds = get_dataset(graph)
    g = build_local_graph(ds.csr, ds.csc)
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(seed)
    base = rng.choice(np.flatnonzero(deg > 0), max_batch,
                      replace=False).astype(np.int64)
    runner = MultiSourceBFSRunner(g, SchedulerConfig(policy=policy))
    for m in plane_wave_sizes(max_batch):
        runner.run(np.resize(base, m))
    ref_rows = np.asarray(runner.run(base).levels, np.int64)
    ref = {int(r): ref_rows[i].copy() for i, r in enumerate(base)}
    icfg = IntegrityConfig(mode=integrity)
    INF = 1 << 30

    def _wave_ok(wave):
        return wave.n_failed == 0 and all(
            np.array_equal(np.asarray(o.levels, np.int64), ref[o.root])
            for o in wave.outcomes)

    # -- clean sweep: no false positives ---------------------------------
    clean_sup = EngineSupervisor(runner, watchdog=False, backoff=0.0,
                                 integrity=icfg)
    clean_all_ok = all(_wave_ok(clean_sup.run_wave(rng.permutation(base)))
                       for _ in range(clean_waves))
    clean_ig = clean_sup.stats()["integrity"]

    # -- plane-word flips: device statvec residue must fire --------------
    def _flip_trial(kind, spec_key, spec):
        eng = FaultyEngine(runner, FaultPlan([(0, kind)]),
                           **{spec_key: spec})
        sup = EngineSupervisor(eng, max_retries=2, backoff=0.0,
                               watchdog=False, integrity=icfg)
        wave = sup.run_wave(base)
        ig = sup.stats()["integrity"]
        return dict(kind=kind, target=list(spec),
                    detected=ig["violations"] >= 1,
                    recovered=_wave_ok(wave),
                    retries=wave.retries)

    flips = []
    for i in range(trials):
        plane = i % max_batch
        # a vertex far from plane's root: XOR at level 1 plants a
        # spurious discovery bit (never a legitimate level-1 frontier
        # member), so detection is deterministic, not frontier-density
        # luck
        far = np.flatnonzero((ref_rows[plane] >= 3)
                             | (ref_rows[plane] == INF))
        vtx = int(far[(7 * i) % far.size])
        flips.append(_flip_trial("plane_flip", "plane_flip",
                                 (1, vtx, plane)))
    for i in range(trials):
        flips.append(_flip_trial(
            "result_flip", "result_flip",
            (i % max_batch, int(base[(3 * i) % base.size]), 16)))
    runner.integrity = "off"     # knobs pushed by the supervisors above
    n_detected = sum(f["detected"] for f in flips)
    n_recovered = sum(f["recovered"] for f in flips)

    # -- overload burst: shedding vs queue-to-miss -----------------------
    svc = min(runner.run(base).seconds for _ in range(3))
    slo = slo_factor * svc
    burst = rng.choice(np.flatnonzero(deg > 0),
                       burst_waves * max_batch, replace=True)

    def _burst_arm(shed):
        b = DynamicBatcher(runner, out_deg=deg, window=min(svc, 0.05),
                           max_batch=max_batch, shed=shed,
                           service_hint=svc)
        futs, rejects = [], []
        for r in burst:
            t0 = time.monotonic()
            try:
                futs.append(b.submit(int(r), deadline=slo))
            except Overloaded:
                rejects.append(time.monotonic() - t0)
        b.close(drain=True)
        served = [f.latency for f in futs if f.exception() is None]
        st = b.stats()
        return dict(
            mode="shed" if shed else "no-shed",
            admitted=len(futs), rejected=len(rejects),
            served=len(served),
            served_p99=round(float(np.percentile(served, 99)), 4),
            slo_miss_rate=st.get("slo_miss_rate", 0.0),
            max_reject_seconds=(round(max(rejects), 6) if rejects
                                else 0.0),
            unresolved=sum(1 for f in futs if not f.done()))

    noshed = _burst_arm(False)
    shed = _burst_arm(True)

    return {
        "graph": graph, "max_batch": max_batch, "policy": policy,
        "integrity_mode": integrity, "trials_per_kind": trials,
        "clean_waves": clean_waves,
        "rows": [noshed, shed],
        "flips": flips,
        "flips_injected": len(flips),
        "flips_detected": n_detected,
        "flips_recovered": n_recovered,
        "detection_rate": round(n_detected / max(len(flips), 1), 4),
        "clean_violations": int(clean_ig["violations"]),
        "clean_checks": int(clean_ig["checks"]),
        "clean_rows_match": bool(clean_all_ok),
        "shed_experiment": dict(
            wave_service_seconds=round(svc, 4), slo=round(slo, 4),
            burst_requests=int(burst.size),
            overload_factor=round(burst_waves / slo_factor, 2),
            served_p99_shed=shed["served_p99"],
            served_p99_noshed=noshed["served_p99"],
            shed_p99_wins=bool(shed["served_p99"]
                               < noshed["served_p99"]),
            rejects_under_one_wave=bool(
                shed["max_reject_seconds"] < svc)),
    }


def check_bitflip(out: dict) -> list[str]:
    """The ``--chaos --bitflip --check`` gate."""
    bad = []
    if out["flips_detected"] != out["flips_injected"]:
        missed = [f["target"] for f in out["flips"] if not f["detected"]]
        bad.append(f"integrity layer missed {missed} "
                   f"({out['flips_detected']}/{out['flips_injected']} "
                   "detected; gate is 100%)")
    if out["flips_recovered"] != out["flips_injected"]:
        bad.append("corrupted waves did not all recover with "
                   "reference-matching rows "
                   f"({out['flips_recovered']}/{out['flips_injected']})")
    if out["clean_violations"]:
        bad.append(f"{out['clean_violations']} false-positive violations "
                   f"on {out['clean_waves']} clean waves (gate is 0)")
    if not out["clean_rows_match"]:
        bad.append("clean sweep rows diverged from the reference")
    sx = out["shed_experiment"]
    if not sx["shed_p99_wins"]:
        bad.append("shedding arm's served p99 "
                   f"({sx['served_p99_shed']}s) did not beat no-shedding "
                   f"({sx['served_p99_noshed']}s) under overload")
    if not sx["rejects_under_one_wave"]:
        bad.append("a shed reject took longer than one wave service "
                   "time")
    for row in out["rows"]:
        if row["unresolved"]:
            bad.append(f"{row['unresolved']} admitted requests never "
                       f"resolved in the {row['mode']} arm")
    return bad


def run_matrix(graph: str = "rmat16-16", requests: int = 128,
               rates: tuple = (128.0, 512.0, 1024.0), slo: float = 2.0,
               passes: int = 3, window: float = 0.25,
               policy: str = "beamer", seed: int = 0) -> dict:
    """Load matrix: Poisson arrival-rate sweep x two serving stacks.

    * ``baseline``  — the pre-PR operating point: dense-pull engine,
      ``max_batch=32`` (one plane word), no pipelining.
    * ``pipelined`` — the production stack: sparse-budgeted-pull engine,
      ``max_batch=96`` (three plane words), cutter/dispatcher/finisher
      pipelining.

    Every request carries ``deadline=slo``, so each cell reports
    p50/p99/p99.9 AND the SLO-miss-rate at that arrival rate.  Shared
    hosts show 30-40% phase noise over seconds, so the two arms are
    measured INTERLEAVED per pass (baseline, pipelined, x``passes``) and
    the gate takes the best SAME-PASS ratio at the saturating (highest)
    rate — the claim is about the serving stack, not about which arm a
    host hiccup landed on (same protocol as the chaos arm's 10% gate).
    """
    ds = get_dataset(graph)
    g = build_local_graph(ds.csr, ds.csc)
    deg = np.diff(ds.csr.indptr)
    rng = np.random.default_rng(seed)
    roots = rng.choice(np.flatnonzero(deg > 0), requests,
                       replace=True).astype(np.int64)
    arms = {
        "baseline": dict(engine=MultiSourceBFSRunner(
            g, SchedulerConfig(policy=policy)),
            max_batch=32, pipeline=False),
        "pipelined": dict(engine=MultiSourceBFSRunner(
            g, SchedulerConfig(policy=policy), sparse_pull=True),
            max_batch=96, pipeline=True),
    }
    for arm in arms.values():
        for m in plane_wave_sizes(arm["max_batch"]):
            arm["engine"].run(np.resize(roots, m))

    def _drive(arm, rate):
        batcher = DynamicBatcher(arm["engine"], out_deg=deg,
                                 window=window,
                                 max_batch=arm["max_batch"],
                                 pipeline=arm["pipeline"])
        t0 = time.monotonic()
        drive_open_loop(batcher, roots, rate=rate,
                        rng=np.random.default_rng(seed + 1), deadline=slo)
        wall = time.monotonic() - t0
        s = batcher.stats()
        s["wall_seconds"] = round(wall, 4)
        s["delivered_teps"] = round(s["traversed_edges"] / max(wall, 1e-12),
                                    1)
        return s

    rows, ratios_by_rate = [], {}
    for rate in rates:
        per_arm = {name: [] for name in arms}
        for _ in range(passes):
            for name, arm in arms.items():   # interleaved: one pass each
                per_arm[name].append(_drive(arm, rate))
        ratios = [p["aggregate_teps"] / max(b["aggregate_teps"], 1e-12)
                  for b, p in zip(per_arm["baseline"],
                                  per_arm["pipelined"])]
        ratios_by_rate[rate] = [round(r, 4) for r in ratios]
        for name in arms:
            best = max(per_arm[name], key=lambda s: s["aggregate_teps"])
            rows.append(dict(
                mode=name, rate=rate, waves=best["waves"],
                busy_seconds=best["busy_seconds"],
                engine_idle_seconds=best["engine_idle_seconds"],
                aggregate_teps=best["aggregate_teps"],
                delivered_teps=best["delivered_teps"],
                latency_p50=best["latency_p50"],
                latency_p99=best["latency_p99"],
                latency_p999=best["latency_p999"],
                slo_miss_rate=best.get("slo_miss_rate", 0.0)))
    sat = max(rates)
    gate_ratio = float(np.max(ratios_by_rate[sat]))
    return {"graph": graph, "requests": requests, "rates": list(rates),
            "slo": slo, "window": window, "passes": passes,
            "policy": policy,
            "arms": {"baseline": dict(max_batch=32, pipeline=False,
                                      sparse_pull=False),
                     "pipelined": dict(max_batch=96, pipeline=True,
                                       sparse_pull=True)},
            "rows": rows,
            "pass_ratios_by_rate": {str(r): v
                                    for r, v in ratios_by_rate.items()},
            "saturating_rate": sat,
            "teps_ratio_pipelined_vs_baseline": round(gate_ratio, 4),
            "gate_1p3x": bool(gate_ratio >= 1.3)}


def check_matrix(out: dict) -> list[str]:
    """The ``--matrix --check`` gate."""
    bad = []
    if not out["gate_1p3x"]:
        bad.append("pipelined multi-word serving fell below the 1.3x "
                   "aggregate-TEPS gate at the saturating rate "
                   f"(ratio {out['teps_ratio_pipelined_vs_baseline']})")
    for row in out["rows"]:
        if "slo_miss_rate" not in row or "latency_p999" not in row:
            bad.append(f"row {row.get('mode')}@{row.get('rate')} is "
                       "missing SLO/percentile accounting")
    return bad


def check_chaos(out: dict) -> list[str]:
    """The ``--chaos --check`` gate: the failures CI would fail on."""
    bad = []
    if out["resolved"] != out["requests"]:
        bad.append(f"only {out['resolved']}/{out['requests']} requests "
                   "resolved (hang)")
    if out["mismatched_roots"]:
        bad.append(f"wrong levels for roots {out['mismatched_roots']}")
    if out["failed_clean_roots"]:
        bad.append(f"clean roots failed: {out['failed_clean_roots']}")
    if not out["poison_quarantined"]:
        bad.append("poisoned root was not quarantined with a typed error")
    if not out["bisection"]["within_bound"]:
        bad.append(f"bisection took {out['bisection']['fault_waves']} "
                   f"fault waves (> bound {out['bisection']['bound']})")
    if "pallas->jnp" not in out["demotion"]["demotions"]:
        bad.append("forced pallas failure did not demote to jnp")
    if not out["demotion"]["oracle_match"]:
        bad.append("demoted wave rows do not match the fault-free oracle")
    if not out["within_10pct"]:
        bad.append("fault-free arm fell outside the 10% serving gate "
                   f"(ratio {out['teps_ratio_dynamic_vs_static']})")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat16-16")
    ap.add_argument("--requests", type=int,
                    help="number of queries (default 96; 64 with --chaos)")
    ap.add_argument("--rate", type=float, default=256.0,
                    help="open-loop Poisson arrival rate, req/s")
    ap.add_argument("--window", type=float,
                    help="coalescing window, seconds "
                         "(default 0.5; 0.25 with --chaos)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--policy", default="beamer")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection arm through the "
                         "EngineSupervisor instead of the plain benchmark")
    ap.add_argument("--bitflip", action="store_true",
                    help="with --chaos: run the bit-flip integrity + "
                         "overload-shedding arm instead of the fault-mix "
                         "stream (plane-word and result-row flips must "
                         "be detected and recovered; shedding must beat "
                         "queue-to-miss under a burst)")
    ap.add_argument("--ft-integrity", default="witness",
                    choices=("invariants", "witness", "audit"),
                    help="detector tier for the --bitflip arm")
    ap.add_argument("--matrix", action="store_true",
                    help="run the load matrix: Poisson rate sweep x "
                         "{baseline single-word, pipelined multi-word} "
                         "with per-rate p50/p99/p99.9 + SLO-miss-rate")
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[128.0, 512.0, 1024.0],
                    help="arrival rates for --matrix (highest = the "
                         "saturating gate point)")
    ap.add_argument("--slo", type=float, default=2.0,
                    help="per-request relative deadline for --matrix")
    ap.add_argument("--passes", type=int, default=3,
                    help="interleaved measurement passes per rate "
                         "(--matrix)")
    ap.add_argument("--fault-rate", type=float, default=0.1,
                    help="per-engine-call Bernoulli fault rate (chaos)")
    ap.add_argument("--out", metavar="PATH",
                    help="also write the result record here "
                         "(e.g. BENCH_msbfs_chaos.json at the repo root)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every request resolved, "
                         "non-poisoned answers match the fault-free "
                         "reference, and the policy bounds held")
    args = ap.parse_args()
    if args.check and not (args.chaos or args.matrix):
        ap.error("--check gates the chaos or matrix arm; add --chaos "
                 "or --matrix")
    if args.chaos and args.matrix:
        ap.error("--chaos and --matrix are separate arms; pick one")
    if args.bitflip and not args.chaos:
        ap.error("--bitflip is a chaos sub-arm; add --chaos")
    if args.bitflip:
        out = run_bitflip(graph=args.graph, max_batch=args.max_batch,
                          policy=args.policy,
                          integrity=args.ft_integrity)
        save("msbfs_integrity", out)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2, default=str)
        print_rows("msbfs_integrity", out["rows"])
        sx = out["shed_experiment"]
        print(f"  flips detected: {out['flips_detected']}"
              f"/{out['flips_injected']} recovered: "
              f"{out['flips_recovered']} clean false positives: "
              f"{out['clean_violations']}/{out['clean_checks']} checks")
        print(f"  burst {sx['burst_requests']} reqs @ slo {sx['slo']}s: "
              f"served p99 shed {sx['served_p99_shed']}s vs no-shed "
              f"{sx['served_p99_noshed']}s; max reject "
              f"{out['rows'][1]['max_reject_seconds']}s "
              f"(< wave {sx['wave_service_seconds']}s: "
              f"{sx['rejects_under_one_wave']})")
        if args.check:
            bad = check_bitflip(out)
            if bad:
                raise SystemExit("bitflip check FAILED: " + "; ".join(bad))
            print("  bitflip check passed: 100% detection, full "
                  "recovery, zero false positives, shedding beats "
                  "queue-to-miss")
        return
    if args.matrix:
        out = run_matrix(graph=args.graph,
                         requests=args.requests or 128,
                         rates=tuple(args.rates), slo=args.slo,
                         passes=args.passes,
                         window=args.window or 0.25,
                         policy=args.policy)
        save("msbfs_serving_matrix", out)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2, default=str)
        print_rows("msbfs_serving_matrix", out["rows"])
        print(f"  pipelined/baseline aggregate TEPS at saturating rate "
              f"{out['saturating_rate']}: "
              f"{out['teps_ratio_pipelined_vs_baseline']} "
              f"(gate >= 1.3x: {out['gate_1p3x']})")
        if args.check:
            bad = check_matrix(out)
            if bad:
                raise SystemExit("matrix check FAILED: " + "; ".join(bad))
            print("  matrix check passed: pipelined multi-word serving "
                  "holds the 1.3x gate with per-rate SLO accounting")
        return
    requests = args.requests or (64 if args.chaos else 96)
    window = args.window or (0.25 if args.chaos else 0.5)
    if args.chaos:
        out = run_chaos(graph=args.graph, requests=requests,
                        fault_rate=args.fault_rate, rate=args.rate,
                        window=window, max_batch=args.max_batch,
                        policy=args.policy)
        save("msbfs_chaos", out)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2, default=str)
        print_rows("msbfs_chaos", out["rows"])
        print(f"  resolved: {out['resolved']}/{out['requests']} "
              f"poison quarantined: {out['poison_quarantined']} "
              f"bisection fault waves: {out['bisection']['fault_waves']} "
              f"(bound {out['bisection']['bound']}) "
              f"demotions: {out['demotion']['demotions']}")
        print(f"  chaos/fault-free aggregate TEPS: "
              f"{out['chaos_teps_ratio_vs_fault_free']}  "
              f"fault-free/static: {out['teps_ratio_dynamic_vs_static']} "
              f"(within 10%: {out['within_10pct']})")
        if args.check:
            bad = check_chaos(out)
            if bad:
                raise SystemExit("chaos check FAILED: " + "; ".join(bad))
            print("  chaos check passed: 100% resolution, differential "
                  "match, bisection + demotion bounds held")
        return
    out = run(graph=args.graph, requests=requests, rate=args.rate,
              window=window, max_batch=args.max_batch,
              policy=args.policy)
    save("msbfs_serving", out)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2, default=str)
    print_rows("msbfs_serving", out["rows"])
    print(f"  dynamic/static aggregate TEPS: "
          f"{out['teps_ratio_dynamic_vs_static']} "
          f"(within 10%: {out['within_10pct']})")


if __name__ == "__main__":
    main()
