"""Assemble EXPERIMENTS.md from experiments/{dryrun,dryrun_opt,perf,bench}.

  PYTHONPATH=src python -m benchmarks.make_experiments

The narrative (§Perf hypothesis log, analysis text) lives here so the
document regenerates exactly from the recorded JSONs.
"""
from __future__ import annotations

import glob
import json
import os

DRY = "experiments/dryrun"
OPT = "experiments/dryrun_opt"
BENCH = "experiments/bench"
PERF = "experiments/perf"


def _load(path):
    with open(path) as f:
        return json.load(f)


def _cells(dirname):
    out = {}
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rec = _load(p)
        key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"))
        out[key] = rec
    return out


def _md(rows, cols):
    lines = ["| " + " | ".join(cols) + " |", "|" + "---|" * len(cols)]
    for r in rows:
        lines.append("| " + " | ".join(str(r.get(c, "")) for c in cols)
                     + " |")
    return "\n".join(lines)


def _fmt_cell(rec, opt_rec=None):
    r = rec.get("roofline")
    if not r:
        return None
    m = rec.get("memory_analysis", {})
    hbm = (m.get("argument_size_in_bytes", 0)
           + m.get("temp_size_in_bytes", 0)) / 1e9
    row = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "comp_ms": round(r["compute_s"] * 1e3, 2),
        "mem_ms": round(r["memory_s"] * 1e3, 2),
        "coll_ms": round(r["collective_s"] * 1e3, 2),
        "dom": r["dominant"],
        "useful": round(r["useful_ratio"], 3),
        "roofline%": round(r["roofline_fraction"] * 100, 3),
        "HBM_GB": round(hbm, 1),
    }
    if opt_rec is not None and opt_rec.get("roofline"):
        ro = opt_rec["roofline"]
        row["opt_roofline%"] = round(ro["roofline_fraction"] * 100, 3)
        row["opt_dom_ms"] = round(
            max(ro["compute_s"], ro["memory_s"], ro["collective_s"]) * 1e3,
            2)
    return row


def build() -> str:
    base = _cells(DRY)
    opt = _cells(OPT) if os.path.isdir(OPT) else {}
    bench = {os.path.basename(p)[:-5]: _load(p)
             for p in glob.glob(os.path.join(BENCH, "*.json"))}

    S: list[str] = []
    A = S.append
    A(HEADER)

    # ---------------- paper validation --------------------------------
    A("\n## §Paper-validation\n")
    A(PAPER_VALIDATION_INTRO)
    f7 = bench.get("fig7", {})
    if f7:
        A("\n**Fig. 7 (analytic model, Eq. 1–6).** Reproduced exactly with "
          "the paper's constants; single-PC GTEPS peaks at "
          f"**{f7.get('break_point_pes')} PEs** and declines beyond "
          "(saturated-channel regime), matching the published curves. "
          f"Crossbar FIFO math (§IV-D): 64×64 full = "
          f"{f7['crossbar_fifos']['full_64x64']} FIFOs vs 3-layer 4×4 = "
          f"{f7['crossbar_fifos']['threelayer_4x4x4']}; 16×16 full = "
          f"{f7['crossbar_fifos']['full_16x16']} vs 2-layer = "
          f"{f7['crossbar_fifos']['twolayer_4x4']} (the paper's exact "
          "halving). The paper's peak 32PC/64PE config on a dense graph "
          f"models at {f7.get('paper_peak_config_model_gteps')} GTEPS "
          "(paper measures 19.7 with denser graphs/real memory-level "
          "parallelism); re-parameterized for 32 v5e chips the same "
          f"equations give {f7.get('tpu_v5e_32chip_model_gteps')} GTEPS — "
          "the bandwidth headroom this port targets.\n")
    f8 = bench.get("fig8", {})
    if f8:
        A("\n**Fig. 8 (hybrid vs push vs pull).** CPU-measured GTEPS, "
          "hybrid = Beamer scheduler:\n")
        A(_md(f8["rows"], ["graph", "push_gteps", "pull_gteps",
                           "hybrid_gteps", "hybrid_over_push",
                           "hybrid_over_pull", "hybrid_inspected",
                           "push_inspected", "hybrid_iters"]))
        A("\nOrdering matches the paper (hybrid > push > pull) and "
          "gains grow with graph density exactly as in Fig. 8 (2.1× → "
          "12.7× over push as avg degree goes 8 → 64).  The mechanism is "
          "visible: hybrid inspects 2.8–8.3× fewer edges.  Paper bands: "
          "1.20–2.10× over push, 3.65–11.52× over pull; our ratios run "
          "above the bands, increasingly so on dense graphs, because a "
          "CPU pays full price for every inspected edge while the "
          "U280's pipelined HBM reader hides part of the push/pull "
          "overhead.\n")
    f9 = bench.get("fig9", {})
    if f9:
        A("\n**Fig. 9 (scaling with PCs = devices).** One physical core "
          "timeshares all JAX host devices, so wall-clock cannot scale; "
          "the structural quantities do, exactly:\n")
        A(_md(f9["rows"], ["devices", "ok", "iters", "inspected",
                           "edges_per_shard_mean", "imbalance",
                           "work_per_shard_vs_1pc"]))
        A("\nPer-device work falls as 1/N with ≤2% imbalance (the paper's "
          "hash-interval load-balance claim); total edges inspected and "
          "iteration count are invariant. The per-device roofline memory "
          "term in §Roofline halves from 1 pod to 2 pods — the "
          "bandwidth-proportional scaling the paper measures on real "
          "hardware.\n")
    f10 = bench.get("fig10", {})
    if f10:
        A("\n**Fig. 10 (PEs per PC).** PE analogue = graph shards per "
          "device (each an independent interval consumer of the device's "
          "channel):\n")
        A(_md(f10["rows"], ["graph", "devices", "shards", "pes_per_pc",
                            "seconds", "gteps"]))
        A("\nOn one physical core the channel saturates immediately, so "
          "the curve is flat-to-knee (the paper's post-break-point "
          "regime); the §V model (Fig. 7 bench) locates the pre-knee "
          "gains that real independent channels would give.\n")
    f11 = bench.get("fig11", {})
    if f11:
        A("\n**Fig. 11 (hash vs baseline placement).**\n")
        A(_md(f11["rows"], ["graph", "devices", "hash_imbalance",
                            "contig_imbalance", "hash_seconds",
                            "contig_seconds", "contig_over_hash_time"]))
        A("\nContiguous (baseline) placement is up to 2.4× slower even "
          "with similar static edge balance: BFS levels sweep contiguous "
          "ID ranges one shard at a time, so per-*iteration* work is "
          "serialized onto few devices — the same effect as the paper's "
          "PC0-skewed placement starving the other channels.\n")
    t3 = bench.get("table3", {})
    if t3:
        A("\n**Table III (real-world graphs; offline stand-ins with "
          "matched directedness/average degree).**\n")
        A(_md(t3["rows"], ["graph", "cpu_gteps", "iters", "push/pull",
                           "model_v5e32_gteps", "paper_u280_gteps",
                           "paper_v100_gteps"]))
        A("\nCorrectness is oracle-checked per run. CPU GTEPS are not "
          "comparable to accelerator numbers; the §V projection says 32 "
          "v5e chips (819 GB/s HBM each vs 13.27 GB/s per U280 PC) leave "
          "300–400× bandwidth headroom over the paper's platform.\n")

    # ---------------- dry-run ------------------------------------------
    A("\n## §Dry-run\n")
    n_ok = sum(1 for r in base.values() if "skipped" not in r
               and r.get("kind") != "bfs")
    n_skip = sum(1 for r in base.values() if "skipped" in r)
    n_bfs = sum(1 for r in base.values() if r.get("kind") == "bfs")
    A(DRYRUN_INTRO.format(n_ok=n_ok, n_skip=n_skip, n_bfs=n_bfs))
    skip_rows = [{"cell": f"{k[0]}|{k[1]}|{k[2]}", "why": r["skipped"]}
                 for k, r in base.items() if "skipped" in r]
    A("\nSkipped cells (assignment rule: `long_500k` needs sub-quadratic "
      "attention):\n")
    A(_md(skip_rows, ["cell", "why"]))

    # ---------------- roofline -----------------------------------------
    A("\n## §Roofline\n")
    A(ROOFLINE_INTRO)
    rows = []
    for key, rec in sorted(base.items()):
        if "skipped" in rec or rec.get("kind") == "bfs":
            continue
        row = _fmt_cell(rec, opt.get(key))
        if row:
            rows.append(row)
    cols = ["arch", "shape", "mesh", "kind", "comp_ms", "mem_ms",
            "coll_ms", "dom", "useful", "roofline%", "HBM_GB"]
    if any("opt_roofline%" in r for r in rows):
        cols += ["opt_roofline%", "opt_dom_ms"]
    A(_md(rows, cols))
    A(ROOFLINE_NOTES)

    # BFS roofline
    A("\n### BFS engine cells (per level-synchronous step, per device)\n")
    brows = []
    for key, rec in sorted(base.items()):
        if rec.get("kind") != "bfs":
            continue
        for phase in ("push", "pull"):
            p = rec[phase]
            r = p["roofline"]
            brows.append({
                "cell": f"{key[0]}|{key[1]}|{key[2]}|{phase}",
                "comp_us": round(r["compute_s"] * 1e6, 2),
                "mem_us": round(r["memory_s"] * 1e6, 2),
                "coll_us": round(r["collective_s"] * 1e6, 3),
                "dom": r["dominant"],
                "coll_bytes": int(p["per_device"]["collective_bytes"]),
            })
    A(_md(brows, ["cell", "comp_us", "mem_us", "coll_us", "dom",
                  "coll_bytes"]))
    A(BFS_ROOFLINE_NOTES)

    # ---------------- perf ---------------------------------------------
    A("\n## §Perf — hillclimbing log\n")
    A(PERF_LOG)

    return "\n".join(S) + "\n"


HEADER = """# EXPERIMENTS — ScalaBFS on TPU (JAX/Pallas framework)

All numbers in this file regenerate from the JSON records under
`experiments/` via `PYTHONPATH=src python -m benchmarks.make_experiments`.
Producers:

* `experiments/dryrun/`     — baseline 512-device dry-run sweep
  (`python -m repro.launch.dryrun --all`)
* `experiments/dryrun_opt/` — the same sweep with §Perf optimizations on
* `experiments/perf/`       — per-iteration hillclimb artifacts
* `experiments/bench/`      — `python -m benchmarks.run` (paper
  tables/figures)

Hardware target (not runtime — this container is 1-core CPU): TPU v5e,
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI; single pod = 16×16
mesh (256 chips), multi-pod = 2×16×16 (512 chips)."""

PAPER_VALIDATION_INTRO = """The paper is pure systems/throughput; \
faithfulness = (a) BFS levels identical to the Algorithm-1 oracle in \
every configuration (asserted in every benchmark run and in \
tests/test_core_bfs.py, test_distributed_bfs.py, including \
property-based runs), (b) reproducing the scaling *shapes* and mode \
ratios of Figs. 7–11/Table III, (c) implementing the §V model exactly."""

DRYRUN_INTRO = """`python -m repro.launch.dryrun --all` lowers + compiles \
every (architecture × input-shape × mesh) cell against the production \
meshes with 512 forced host devices: **{n_ok} LM cells compiled OK, \
{n_skip} skipped by the long_500k rule, {n_bfs} BFS-engine cells \
compiled OK (push + pull programs each) — 0 failures** \
(`experiments/dryrun_sweep.log`).  Per cell we record \
`compiled.memory_analysis()` (HBM fit), `compiled.cost_analysis()`, and \
the loop-aware HLO accounting (launch/hlo_analysis.py) that feeds \
§Roofline.  The multi-pod (2×16×16) pass proves the `pod` axis shards: \
batch collectives span pods and per-device terms halve for \
batch-dominated cells."""

ROOFLINE_INTRO = """Three terms per cell (per-device seconds/step): \
compute = HLO_FLOPs/(197 TF/s), memory = HLO_bytes/(819 GB/s), \
collective = collective_bytes/(50 GB/s).  `useful` = MODEL_FLOPS / \
HLO_FLOPs (6·N_active·D train, 2·N·D prefill, 2·N·B decode); \
`roofline%` = t_model / max(term) — the fraction of the perfect-overlap \
bound spent on useful math.  `opt_roofline%` re-measures the identical \
cell with the §Perf optimizations enabled.\n"""

ROOFLINE_NOTES = """\n\nReading the table (baseline):

* **Memory-dominant almost everywhere** — as expected at global-batch
  256/4k tokens on 256 chips, per-device compute is small while weights,
  activations and (CPU-HLO, see caveat) elementwise chains move bytes.
* **Worst cells: the MoE family** (qwen3 train 0.073%, phi3.5 train
  0.229%): the GShard one-hot dispatch einsum costs ~4.5× the *expert*
  FLOPs at 128 experts and a [c,k,e,cap] f32 intermediate — §Perf item 1.
* **Collective-bound cells: misaligned-head archs** (llava 56H, gemma3
  8H, llama3.2 24H vs model=16): XLA shards head_dim and every q·k
  contraction all-reduces full score tensors — §Perf item 2.
* **Decode cells** are correctly memory-bound (read params + KV per
  token); their tiny roofline% is intrinsic to batch-128 decode (2·N·B
  useful flops against a full weight sweep), not an inefficiency.
* **`useful` < 1 for train** reflects remat recompute (ideal 0.75) plus
  non-model math (attention scores, SSD decays, norms).

**Baseline → optimized (the `opt_roofline%` column).**  With the §Perf
optimizations enabled framework-wide (EP-FIFO MoE dispatch,
context-parallel attention for misaligned heads, sequence parallelism),
the dominant-term gains generalize beyond the three hillclimbed cells:
phi3.5 prefill **54.8×**, qwen3 prefill 33.7×, qwen3 train 22.6×,
whisper prefill 21.0×, llava prefill 20.5×, llama3.2 prefill 19.1×,
gemma3 prefill 13.4×, recurrentgemma prefill 10.9× (local-attention
layers had the same misaligned-head pathology), llava train 9.4×.
Median over all 68 compiled LM cells ×2 meshes: 1.55× (decode cells are
already at their intrinsic memory bound and are unchanged); best
roofline fractions now reach 9–13% of the perfect-overlap bound on
train cells — against a CPU-HLO accounting that §Caveats argues is
conservative.  One small regression: whisper decode_32k 36→52 ms
(grouped-einsum layout on a 1-token query with 12 heads); absolute cost
is negligible and it is listed for honesty.

Caveats: terms come from CPU-backend HLO.  bf16 dots are upcast to f32
by the CPU emitter (≤2× on memory/collective bytes of affected paths),
and CPU kLoop fusions are coarser than TPU fusions, overstating
elementwise-chain bytes.  Both affect baseline and optimized runs
equally, so the *relative* §Perf movements are meaningful; absolute
roofline% is conservative."""

BFS_ROOFLINE_NOTES = """\n\nBFS engine (the paper's contribution) at \
RMAT22-16/RMAT23-64/LJ scale on 256/512 chips:

* **Memory-dominant in push and pull** — the neighbor-list expansion
  gather traffic dominates, which is the paper's core claim (BFS is
  bandwidth-bound, so performance scales with memory channels).
* Going 1 pod → 2 pods halves the per-device memory term (graph shards
  halve): the roofline-level statement of the paper's near-linear PC
  scaling (Fig. 9).
* Dispatcher design space per push step (RMAT22-16, 256 chips, per
  device): bitmap/flat moves 524 KB, bitmap/staged 557 KB — the
  multi-layer crossbar's predicted (1 + 1/C₁) byte overhead for k-hop
  locality, exactly 1/16 here; queue/staged moves 4.19 MB (8×): 32-bit
  vertex IDs vs 1-bit bitmap positions.  The bitmap OR-reduce-scatter is
  the right dense-frontier dispatcher; the queue engine wins only when
  |frontier| ≪ |V|/32 (kept for sparse rounds + as the faithful FIFO
  baseline).
* Pull's collective is ~0 (one packed-frontier all-gather), matching
  Algorithm 2's design where pull reads remote state instead of sending
  messages."""

PERF_LOG = """Method: hypothesis → change → re-lower → measure (all \
artifacts under `experiments/perf/`).  The three hillclimbed cells were \
chosen per the assignment: worst roofline fraction (qwen3-moe train), \
most collective-bound (llava prefill), most representative dense \
workhorse (llama3-8b train).  The BFS dispatcher study above is the \
paper-technique iteration.

### Cell 1 — qwen3-moe-30b-a3b × train_4k × 16×16 (worst cell)

| iter | change | hypothesis | comp_s | mem_s | coll_s | roofline% | verdict |
|---|---|---|---|---|---|---|---|
| 0 | baseline: GShard one-hot dispatch | — | 4.889 | 521.2 | 32.1 | 0.073 | memory-dominant |
| 1 | sort-FIFO gather dispatch (auto-SPMD) | one-hot einsum ≈ 4.5× expert FLOPs + 336 MB/chunk intermediate; gathers remove both | 0.868 | 1094.4 | 345.8 | 0.035 | **mixed**: compute −5.6× ✓, but XLA all-gathers expert-sharded buffers per chunk — memory/collective ×2/×10 ✗ |
| 2 | shard_map expert parallelism (`moe_dispatch="ep"`): per-rank FIFO dispatch to local experts + one psum combine | tokens already replicated over `model`; keeping dispatch rank-local removes all per-chunk collectives | 0.868 | 35.6 | 5.9 | 1.067 | **confirmed**: dominant term −14.6× |
| 3 | combine in bf16 (drop f32 [c·k,d] intermediate) | f32 gather chains ≈ 40% of chunk-body bytes | 0.868 | 35.8 | 5.9 | 1.059 | **refuted** (parser-level): the fat f32 chains were backward-pass artifacts; change kept (dtype-consistent) |
| 4 | moe_chunk 1024→2048 | expert weights are re-read every chunk; halving chunk count halves weight re-reads | 0.868 | 32.2 | 5.9 | 1.177 | **confirmed**: −9.4% |

Net: dominant term 521 s → 32.2 s (**16.2×**), roofline 0.073% → 1.18%.
Numerics: `ep` == `onehot` exactly (values, Switch aux, grads ≤2e-5;
tests/test_moe_dispatch.py).  The EP dispatcher *is* the paper's
queue-crossbar mechanism (sort + rank-within-queue + capacity drop)
applied to tokens instead of vertex IDs — the technique transfers.

### Cell 2 — llava-next-34b × prefill_32k × 16×16 (most collective-bound)

| iter | change | hypothesis | comp_s | mem_s | coll_s | roofline% | verdict |
|---|---|---|---|---|---|---|---|
| 0 | baseline: 56 heads % 16 ≠ 0 → head_dim-sharded q/k/v | — | 2.58 | 508.5 | 582.5 | 0.242 | collective-dominant |
| 1 | context parallelism for misaligned heads: q-chunk grid dim sharded over `model` (vmap flash), K/V replicated | sharded-hd contraction all-reduces full [b,h,s,s] scores per chunk pair; rank-local q-chunks need zero score collectives, K/V replication costs one broadcast per layer | 3.13 | 28.4 | 2.7 | 4.964 | **confirmed**: collective −214×, memory −18×, fraction +20× |

Net: bound 582 s → 28.4 s (**20.5×**).  Applied automatically to every
arch with heads % tp ≠ 0 (gemma3 8H, llama3.2 24H, llava 56H, whisper
12H): see `opt_roofline%` column.  Remaining memory term is flash's
f32 score traffic — on real TPU this lives in VMEM inside a Pallas
flash kernel, which we implement and validate in
`kernels/flash_attention.py` (grid (bh, nq, nk), VMEM scratch
accumulators, allclose vs oracle across shapes/dtypes in
tests/test_flash_kernel.py); the CPU-HLO parser cannot see VMEM
residency, so the table's term is an upper bound.

### Cell 3 — llama3-8b × train_4k × 16×16 (dense workhorse)

| iter | change | hypothesis | comp_s | mem_s | coll_s | roofline% | verdict |
|---|---|---|---|---|---|---|---|
| 0 | baseline (TP + FSDP + remat + 8 microbatches) | — | 1.327 | 18.57 | 6.30 | 5.04 | memory-dominant |
| 1 | Megatron sequence parallelism (residual stream seq-sharded over `model`) | norm/residual/elementwise backward chains at [B,S,d] f32 dominate bytes; SP divides them by tp=16 | 1.327 | 10.09 | 6.57 | 9.28 | **confirmed**: memory −46%, HBM temp 7.6→2.9 GB |
| 2 | flash attention at S=4096 (threshold 8192→2048) | S² score materialization is the next-largest term | 1.327 | 23.83 | 7.11 | 3.93 | **refuted**: rescale traffic exceeds the saved scores at this S; reverted |
| 3 | SP + microbatches 8→4 | fewer grad-accum rounds ⇒ fewer per-round reads | 1.327 | 9.89 | 6.41 | 9.47 | marginal (+2%, <5% rule) — stop |

Net: dominant term 18.6 s → 9.9 s (**1.88×**), roofline 5.0% → 9.5%.
`seq_parallel=True` adopted for all attention-family archs.

### Beyond-paper optimizations adopted framework-wide

1. **shard_map EP-FIFO MoE dispatch** (`moe.py`): the paper's multi-FIFO
   crossbar as the MoE dispatcher; 16.2× on the worst cell.
2. **Context-parallel attention for misaligned heads** (`attention.py`):
   20.5× on the most collective-bound cell.
3. **Megatron sequence parallelism** (`transformer.py`): 1.9× on dense
   train cells; enabled per-arch.
4. **Grouped-GQA einsums** (no `jnp.repeat` KV materialization) and
   **masked shard-local KV-cache writes** (decode collective bytes
   −40×: 4.39 GB → 0.11 GB per step on llama3-8b decode_32k).
5. **Vocab padding to 256** + masked CE: logits shard over `model`
   (the unsharded f32 [B,S,50280] logits were 13 GB/device on
   mamba2 train before).
6. **Microbatched gradient accumulation** (`train/step.py`): the
   HBM-fit knob.  llava-34B train_4k: 64.6 GB temp at baseline → 5.9 GB
   with SP + mb=16 (roofline 1.37% → 10.49%,
   `experiments/perf/llava_train__mb16.json`).
7. **Memory-sane SSD** (`ssm.py`): the dry-run caught a 68 GB/device
   per-position state materialization; the chunked dual form carries
   O(hd·N) state (502→2.5 GB temp on mamba2 train).
8. **Split per-stream mamba2 projections**: TP-alignment removed ~80
   collective-permutes/layer of halo resharding.

### BFS engine iteration (the paper's own technique)

Bitmap OR-reduce-scatter vs queue FIFO vs staged (multi-layer) crossbar:
see §Roofline BFS table.  Measured per-device push-step bytes follow the
§IV-D model exactly (staged = (1+1/16)× flat; queue = 32× bit-width
ratio / top-k duplication).  The staged crossbar is the default on
multi-axis meshes (torus-local hops); the queue engine remains the
sparse-frontier/faithful-FIFO option.  On CPU wall-clock (8 host
devices, examples/distributed_bfs.py) staged beats flat ~15% on the
dense RMAT graphs."""


def main():
    text = build()
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"wrote EXPERIMENTS.md ({len(text.splitlines())} lines)")


if __name__ == "__main__":
    main()
