"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only fig8  # one benchmark
  PYTHONPATH=src python -m benchmarks.run --quick      # small graphs only

Results print as CSV blocks and are saved under experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (fig7_perf_model, fig8_hybrid_modes, fig9_pc_scaling,
                        fig10_pe_scaling, fig11_partitioning,
                        msbfs_throughput, roofline_report, table3_real_graphs)
from benchmarks.common import print_rows, save

BENCHES = {
    "fig7": ("perf model Eq.1-7 / Fig.7 curves + crossbar math",
             lambda quick: fig7_perf_model.run()),
    "fig8": ("hybrid vs push vs pull GTEPS (Fig.8)",
             lambda quick: fig8_hybrid_modes.run(
                 graphs=("rmat18-8", "rmat18-16") if quick
                 else fig8_hybrid_modes.GRAPHS)),
    "fig9": ("PC (device) scaling (Fig.9)",
             lambda quick: fig9_pc_scaling.run(
                 device_counts=(1, 2, 4) if quick else (1, 2, 4, 8))),
    "fig10": ("PEs per PC scaling (Fig.10)",
              lambda quick: fig10_pe_scaling.run(
                  graphs=("rmat18-8",) if quick
                  else ("rmat18-8", "rmat18-64"),
                  pes=(1, 2, 4) if quick else (1, 2, 4, 8))),
    "fig11": ("hash vs contiguous placement (Fig.11)",
              lambda quick: fig11_partitioning.run(
                  graphs=("rmat18-16",) if quick
                  else ("rmat18-16", "lj-like"))),
    "msbfs": ("MS-BFS aggregate TEPS vs concurrent batch size",
              lambda quick: msbfs_throughput.run(
                  graph="rmat14-8" if quick else "rmat16-16",
                  batch_sizes=(1, 4, 16) if quick else (1, 2, 4, 8, 16, 32))),
    "table3": ("real-world graph throughput (Table III)",
               lambda quick: table3_real_graphs.run()),
    "roofline": ("dry-run roofline aggregation (§Roofline)",
                 lambda quick: roofline_report.run()),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    names = args.only or list(BENCHES)
    failures = 0
    for name in names:
        desc, fn = BENCHES[name]
        print(f"\n=== {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            out = fn(args.quick)
        except Exception:
            traceback.print_exc()
            failures += 1
            continue
        out["bench_seconds"] = round(time.time() - t0, 1)
        save(name, out)
        rows = out.get("rows", [])
        print_rows(name, rows)
        for k, v in out.items():
            if k not in ("rows", "bfs_rows"):
                print(f"  {k}: {v}" if not isinstance(v, (list, dict))
                      else f"  {k}: {str(v)[:200]}")
        print(f"  [{time.time()-t0:.1f}s]", flush=True)
    return failures


if __name__ == "__main__":
    sys.exit(main())
