"""Paper Fig. 9: performance scaling with the number of HBM PCs.

PC analogue = one mesh device owning one graph shard (DESIGN.md §2).  Each
point runs in a subprocess with N forced host devices and N shards.

This container has ONE physical core, so wall-clock cannot show the
speedup a real pod would (all "devices" timeshare the core).  The
structural scaling quantities are what we validate: per-device work
(edges/shard) falls as 1/N with bounded imbalance (the paper's
load-balance argument for hash partitioning), total edges inspected stays
constant, and the level-synchronous iteration count is unchanged.  GTEPS
is reported for reference.
"""
from __future__ import annotations

from benchmarks.common import run_subprocess

CODE = """
import numpy as np, jax, json
from repro.compat import make_mesh
from repro.graph import get_dataset
from repro.core import bfs_oracle, partition_graph
from repro.core.bfs_distributed import DistributedBFS, DistConfig
import time

N = {devices}
ds = get_dataset("{graph}")
pg = partition_graph(ds.csr, ds.csc, N)
mesh = make_mesh((N,), ("data",))
eng = DistributedBFS(pg, mesh, cfg=DistConfig(dispatch="bitmap",
                                              crossbar="flat"))
deg = np.diff(ds.csr.indptr)
root = int(np.argmax(deg))
lev = eng.run(root)            # warm-up + correctness
ok = bool(np.array_equal(np.minimum(lev, 1<<30),
                         np.minimum(bfs_oracle(ds.csr, root), 1<<30)))
t0 = time.perf_counter(); lev = eng.run(root); dt = time.perf_counter()-t0
trav = int(deg[lev < (1<<30)].sum())
per_shard = (pg.out_indptr[:, -1]).astype(float)
print(json.dumps(dict(devices=N, ok=ok, seconds=round(dt,3),
    gteps=round(trav/dt/1e9, 5), iters=eng.last_stats["iterations"],
    inspected=eng.last_stats["edges_inspected"],
    edges_per_shard_mean=float(per_shard.mean()),
    edges_per_shard_max=float(per_shard.max()))))
"""


def run(graph: str = "rmat18-16", device_counts=(1, 2, 4, 8)) -> dict:
    rows = []
    for n in device_counts:
        out = run_subprocess(CODE.format(devices=n, graph=graph), devices=n)
        out["imbalance"] = round(
            out["edges_per_shard_max"] / max(out["edges_per_shard_mean"],
                                             1e-9), 3)
        rows.append(out)
    base = rows[0]
    for r in rows:
        r["work_per_shard_vs_1pc"] = round(
            r["edges_per_shard_mean"] / base["edges_per_shard_mean"], 4)
    return {"graph": graph, "rows": rows}
