"""Sharded checkpointing: atomic, async-capable, elastic on restore.

Format: one .npz per checkpoint step holding every pytree leaf (addressed by
its flattened key path) + a manifest.  Saves go through a temp dir + rename
(atomic w.r.t. crashes); `save_async` runs the serialization off-thread so
the train loop keeps stepping (the paper-scale analogue: BFS state is just
3 bitmaps + the level array, so checkpoints are cheap and frequent).

Restore is *elastic*: leaves are loaded as host arrays and re-placed with
whatever shardings the (possibly different-shape) new mesh dictates.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "name", k)))
                     for k in path) for path, _ in leaves]
    return keys, [leaf for _, leaf in leaves], treedef


def _encode(a: np.ndarray) -> np.ndarray:
    """npz cannot store ml_dtypes (bf16 etc.); view them as uint16/uint8."""
    a = np.asarray(a)
    if a.dtype.kind == "V" or a.dtype.name in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
    return a


def _flatten(tree):
    keys, leaves, treedef = _paths(tree)
    arrays = {k: _encode(v) for k, v in zip(keys, leaves)}
    dtypes = {k: str(np.asarray(v).dtype) for k, v in zip(keys, leaves)}
    return arrays, dtypes, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, dtypes, _ = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "num_leaves": len(flat), "dtypes": dtypes,
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Serialize+write on a background thread; at most one in flight."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device->host here

        def work():
            save(self.ckpt_dir, step, host_tree, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load leaves and re-place onto devices.

    ``like_tree`` provides the pytree structure (e.g. abstract params);
    ``shardings`` (same structure) enables elastic re-sharding onto a new
    mesh — leaves are host arrays re-placed shard-by-shard.
    """
    path = os.path.join(ckpt_dir, f"step-{step:08d}")
    z = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as mf:
        dtypes = json.load(mf).get("dtypes", {})
    keys, abstract, treedef = _paths(like_tree)

    def _decode(k, arr):
        want = dtypes.get(k)
        if want and str(arr.dtype) != want:
            import ml_dtypes  # noqa: F401 (registers bf16 etc.)
            return arr.view(np.dtype(want))
        return arr

    tree = jax.tree_util.tree_unflatten(
        treedef, [_decode(k, z[k]) for k in keys])
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return tree, manifest
