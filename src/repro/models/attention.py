"""GQA attention: full, sliding-window, chunked (flash-style), and decode.

Sharding posture (see launch/shardings.py): activations shard batch over
(pod, data); projections shard heads / d_ff over `model`.  Decode KV caches
shard the *sequence* dim over `model` (distributed flash-decoding: XLA
partial-softmax + combine), which is what makes 32k/500k-token caches fit.

GQA is computed with grouped einsums (q reshaped to [B, S, hkv, groups,
hd]) rather than `jnp.repeat` of K/V: the repeat materializes a
groups-times-larger KV copy per layer (caught as 4x f32 copies in the
decode dry-run).  The decode cache write is a masked `where` on the local
iota rather than a dynamic-update-slice: a DUS indexes the *sharded*
sequence dim dynamically, which forces XLA to all-gather the cache shard
per layer; the mask is shard-local.  Trade-off vs. in-place DUS aliasing
is discussed in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import psharding as psh
from repro.models.layers import rope

NEG_INF = -1e30


def attn_params(key, d: int, h: int, hkv: int, hd: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    s = 1.0 / float(np.sqrt(d))
    so = 1.0 / float(np.sqrt(h * hd))
    return {
        "wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h, hd, d), dtype) * so,
    }


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   q_positions=None, k_positions=None):
    """Masked full attention.  q: [B,Sq,H,hd]; k/v: [B,Sk,Hkv,hd]."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / float(np.sqrt(hd))
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if k_positions is None:
        k_positions = jnp.arange(k.shape[1])
    qp = q_positions[:, None]
    kp = k_positions[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool) if causal is False else (kp <= qp)
    if window:
        mask = mask & (kp > qp - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, sq, h, hd)


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    chunk_q: int = 1024, chunk_k: int = 1024,
                    shard_q_chunks: bool = False):
    """Chunked online-softmax attention (pure-JAX flash) for long sequences.

    Outer scan over q chunks, inner scan over kv chunks with block masking.
    Peak temp is [B, H, chunk_q, chunk_k] instead of [B, H, S, S].
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    nq, nk = s // chunk_q, s // chunk_k
    assert s % chunk_q == 0 and s % chunk_k == 0, (s, chunk_q, chunk_k)
    qc = q.reshape(b, nq, chunk_q, hkv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, nk, chunk_k, hkv, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, chunk_k, hkv, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / float(np.sqrt(hd))

    def q_step(_, qi_and_i):
        qi, iq = qi_and_i                    # qi: [b, hkv, g, cq, hd]
        q_pos = iq * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, kv_and_j):
            m, l, acc = carry
            kj, vj, jk = kv_and_j            # kj: [b, hkv, ck, hd]
            k_pos = jk * chunk_k + jnp.arange(chunk_k)
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", qi, kj).astype(jnp.float32)
            sc = sc * scale
            mask = jnp.ones((chunk_q, chunk_k), bool)
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(qi.dtype), vj
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), ()

        m0 = jnp.full((b, hkv, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    if shard_q_chunks:
        # context parallelism for misaligned-head archs: the q-chunk grid
        # dim shards over `model` (each rank owns nq/tp chunks against the
        # full K/V), so no sharded-contraction all-reduces appear.  vmap
        # instead of scan makes the grid dim a real shardable dim.
        qc = psh.constrain(qc, "q_chunks")
        out = jax.vmap(lambda qi, iq: q_step(None, (qi, iq))[1])(
            qc, jnp.arange(nq))
    else:
        _, out = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    # out: [nq, b, hkv, g, chunk_q, hd] -> [b, s, h, hd]
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, hd)


def attention_block(x, p, *, positions, causal=True, window=0,
                    rope_theta=500000.0, flash_threshold=8192,
                    kv_override=None):
    """Projection + RoPE + attention + output projection.

    kv_override: (k, v) for cross-attention (already projected+roped).
    """
    b, s, d = x.shape
    h = p["wq"].shape[1]
    # Head-sharded attention needs heads % tp == 0; otherwise XLA shards
    # head_dim and every q.k contraction becomes a sharded-dim all-reduce
    # of the full score tensor (measured 582 s collective at the llava
    # prefill cell).  Misaligned archs switch to context parallelism:
    # q rows shard over `model`, K/V replicate, attention is rank-local.
    aligned = h % psh.tp_size() == 0
    q_hint = (("batch", None, "heads", "head_dim") if aligned
              else ("batch", "q_seq", None, None))
    kv_hint = (("batch", None, "kv_heads", "head_dim") if aligned
               else ("batch", None, None, None))
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = psh.constrain(q, *q_hint)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        k = psh.constrain(k, *kv_hint)
        v = psh.constrain(v, *kv_hint)
        if rope_theta:
            q = rope(q, positions, rope_theta)
            k = rope(k, positions, rope_theta)
    else:
        k, v = kv_override
        if rope_theta:
            q = rope(q, positions, rope_theta)
    if s > flash_threshold and kv_override is None and k.shape[1] == s:
        o = flash_attention(q, k, v, causal=causal, window=window,
                            shard_q_chunks=not aligned)
    else:
        o = full_attention(q, k, v, causal=causal, window=window,
                           q_positions=positions[0] if positions.ndim > 1
                           else positions)
    o = psh.constrain(o, *q_hint)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, hkv: int, length: int, hd: int, dtype):
    return {
        "k": jnp.zeros((batch, length, hkv, hd), dtype),
        "v": jnp.zeros((batch, length, hkv, hd), dtype),
    }


def attention_decode(x, p, cache, pos, *, window=0, rope_theta=500000.0):
    """One-token decode.  x: [B, 1, d]; cache k/v: [B, L, Hkv, hd].

    For windowed layers the cache is a ring buffer of length `window`
    (slot = pos % window); for global layers it is the full sequence.
    The write is a masked `where` over the (sequence-sharded) cache so it
    stays shard-local; the partial softmax over the sharded length is
    XLA's flash-decode combine.  Returns (out [B,1,d], new_cache).
    """
    b, _, d = x.shape
    length = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    posb = jnp.full((b, 1), pos)
    if rope_theta:
        q = rope(q, posb, rope_theta)
        k_new = rope(k_new, posb, rope_theta)
    slot = pos % length if window else jnp.minimum(pos, length - 1)
    idx = jnp.arange(length)
    wmask = (idx == slot)[None, :, None, None]
    ck = jnp.where(wmask, k_new.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(wmask, v_new.astype(cache["v"].dtype), cache["v"])
    ck = psh.constrain(ck, "batch", "kv_seq", None, None)
    cv = psh.constrain(cv, "batch", "kv_seq", None, None)
    # slot validity: ring slots hold positions pos-window+1..pos; full cache
    # slots 0..pos.
    if window:
        cycle = (pos // length) * length
        slot_pos = jnp.where(idx <= slot, cycle + idx, cycle - length + idx)
        valid = (slot_pos >= 0) & (slot_pos <= pos)
    else:
        valid = idx <= pos
    h = q.shape[2]
    hkv = ck.shape[2]
    g = h // hkv
    qg = q.reshape(b, 1, hkv, g, hd_ := q.shape[-1])
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck).astype(jnp.float32)
    sc = sc / float(np.sqrt(hd_))
    sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
    pattn = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pattn, cv).reshape(b, 1, h, hd_)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": ck, "v": cv}
