"""Griffin RG-LRU recurrent block [arXiv:2402.19427] (recurrentgemma).

Block = (temporal conv1d width 4) -> RG-LRU gated linear recurrence:

    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = a^(c * r_t)   with  a = sigmoid(Lambda),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in the Griffin recurrent-branch structure: linear in, GeLU gate
branch, linear out.  Training uses an associative scan; decode carries
(conv window, h) in the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import psharding as psh

_C = 8.0


def rglru_params(key, d: int, width: int, conv_width: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    s = 1.0 / float(np.sqrt(d))
    sw = 1.0 / float(np.sqrt(width))
    return {
        "w_x": jax.random.normal(ks[0], (d, width), dtype) * s,
        "w_gate_branch": jax.random.normal(ks[1], (d, width), dtype) * s,
        "conv_w": jax.random.normal(ks[2], (conv_width, width), dtype) * 0.5,
        "conv_b": jnp.zeros((width,), dtype),
        "w_r": jax.random.normal(ks[3], (width, width), dtype) * sw,
        "w_i": jax.random.normal(ks[4], (width, width), dtype) * sw,
        "lam": jnp.asarray(np.random.default_rng(2).uniform(2.0, 5.0, width),
                           jnp.float32),
        "w_out": jax.random.normal(ks[5], (width, d), dtype) * sw,
    }


def _conv(u, w, b):
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(up[:, i: i + u.shape[1], :] * w[i] for i in range(k)) + b


def _gates(x, p):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_r"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_i"])
                       .astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(-p["lam"])   # log(sigmoid(lam)^(c r))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * \
        x.astype(jnp.float32)
    return a, gated


def rglru_forward(x_in: jax.Array, p: dict) -> jax.Array:
    """x_in: [B, S, d] -> [B, S, d]."""
    x = jnp.einsum("bsd,dw->bsw", x_in, p["w_x"])
    x = psh.constrain(x, "batch", None, "ff")
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x_in, p["w_gate_branch"])
                       .astype(jnp.float32))
    x = _conv(x, p["conv_w"], p["conv_b"])
    a, gated = _gates(x, p)
    a = psh.constrain(a, "batch", None, "ff")
    gated = psh.constrain(gated, "batch", None, "ff")

    def assoc(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(assoc, (a, gated), axis=1)
    y = (h * gate).astype(x_in.dtype)
    return jnp.einsum("bsw,wd->bsd", y, p["w_out"])


def rglru_init_cache(batch: int, width: int, conv_width: int, dtype):
    return {"conv": jnp.zeros((batch, conv_width - 1, width), dtype),
            "h": jnp.zeros((batch, width), jnp.float32)}


def rglru_decode(x_in: jax.Array, p: dict, cache: dict):
    """x_in: [B, 1, d]."""
    x = jnp.einsum("bsd,dw->bsw", x_in, p["w_x"])[:, 0]
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x_in, p["w_gate_branch"])
                       .astype(jnp.float32))[:, 0]
    hist = jnp.concatenate([cache["conv"], x[:, None]], axis=1)
    x = jnp.einsum("bkw,kw->bw", hist, p["conv_w"]) + p["conv_b"]
    a, gated = _gates(x[:, None], p)
    h = a[:, 0] * cache["h"] + gated[:, 0]
    y = (h * gate).astype(x_in.dtype)
    out = jnp.einsum("bw,wd->bd", y, p["w_out"])[:, None]
    return out, {"conv": hist[:, 1:], "h": h}
