"""Logical activation-sharding rules (MaxText-style named axes).

XLA's sharding propagation is greedy: without hints it happily replicates
the batch dim of a large intermediate (we caught it materializing global-
batch SSD states in the mamba2 dry-run).  Model code therefore annotates
activations with *logical* axis names; `constrain` maps them onto whatever
mesh axes exist at trace time (ambient mesh, set by the step builders via
``repro.compat.use_mesh``) and skips any assignment that does not
divide evenly.  Outside a mesh context it is a no-op, so unit tests on one
device run the same code.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

# logical axis -> preferred mesh axes (first-fit with divisibility)
RULES: dict[str | None, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),   # GQA fallback when heads % model != 0
    "ff": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "kv_seq": ("model",),     # decode: shard the KV length (flash-decode)
    "q_seq": ("model",),      # misaligned-head attention: shard q rows
    "q_chunks": ("model",),   # flash: shard the q-chunk grid dim
    "seq": (),                # sequence stays unsharded in the baseline
    "embed": (),
    "state": (),
    None: (),
}


def spec_for(shape: tuple[int, ...], logical: tuple[str | None, ...],
             mesh) -> P | None:
    names = set(mesh.axis_names)
    sizes = dict(mesh.shape)
    entries: list = []
    used: set[str] = set()
    for dim in range(len(shape)):
        name = logical[dim] if dim < len(logical) else None
        axes = tuple(a for a in RULES.get(name, ())
                     if a in names and a not in used)
        size = math.prod(sizes[a] for a in axes) if axes else 1
        if axes and shape[dim] % size == 0 and size > 1:
            entries.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            # try a shorter prefix (e.g. batch=("pod","data") -> ("data",))
            hit = None
            for a in axes:
                if shape[dim] % sizes[a] == 0 and sizes[a] > 1:
                    hit = a
                    break
            entries.append(hit)
            if hit:
                used.add(hit)
    if all(e is None for e in entries):
        return None
    return P(*entries)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate ``x``'s dims with logical axes; no-op without a mesh."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    spec = spec_for(x.shape, logical, mesh)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, compat.constraint_sharding(mesh, spec))


def tp_size() -> int:
    """Size of the tensor-parallel ('model') axis at trace time (1 if no
    ambient mesh)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return 1
    return int(mesh.shape["model"])
