"""Mixture-of-Experts MLP: top-k routing, two dispatch engines.

* ``gather`` (default) — sort-based capacity-FIFO dispatch: (token, slot)
  pairs are sorted by expert, ranked within their expert queue (the exact
  mechanism of the BFS engine's queue crossbar / the paper's FIFO
  dispatcher), and moved with gathers/scatters.  Zero matmul FLOPs spent
  on routing.
* ``onehot`` — the faithful GShard baseline: a dense [c, k, e, cap]
  one-hot dispatch einsum.  At 128 experts this costs ~4.5x the *expert*
  FLOPs and a 300+ MB intermediate per 1k-token chunk (measured in the
  qwen3-moe dry-run; see EXPERIMENTS.md §Perf) — kept as the comparison
  baseline.

Both are chunked over tokens with `lax.scan` so intermediates stay small;
overflowed tokens fall through the residual (standard capacity-factor
semantics).  Expert weights are stacked [E, ...] and sharded over the
`model` axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import psharding as psh


def moe_params(key, d: int, f: int, e: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    s_in = 1.0 / float(np.sqrt(d))
    s_out = 1.0 / float(np.sqrt(f))
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * s_out,
    }


def _expert_ffn(xe, p, dtype):
    """xe: [e, cap, d] -> [e, cap, d] (stacked-expert swiglu)."""
    g = jax.nn.silu(jnp.einsum("eod,edf->eof", xe,
                               p["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("eod,edf->eof", xe, p["w_up"]).astype(jnp.float32)
    ye = jnp.einsum("eof,efd->eod", (g * u).astype(dtype), p["w_down"])
    return psh.constrain(ye, "experts", None, None)


def _chunk_onehot(xi, probs, p, *, top_k, e, cap, chunk):
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # [c, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # [c, k, e]
    # position of each (token, slot) within its expert queue
    pos = jnp.cumsum(onehot.reshape(-1, e), axis=0).reshape(
        chunk, top_k, e) * onehot - 1.0
    fits = (pos >= 0) & (pos < cap)
    disp = jax.nn.one_hot(jnp.where(fits, pos, cap).astype(jnp.int32),
                          cap, dtype=jnp.float32) * fits[..., None]
    # dispatch: [c,k,e,cap] x [c,d] -> [e, cap, d]
    xe = jnp.einsum("ckeo,cd->eod", disp, xi.astype(jnp.float32))
    xe = psh.constrain(xe.astype(xi.dtype), "experts", None, None)
    ye = _expert_ffn(xe, p, xi.dtype)
    comb = jnp.einsum("ckeo,ck->ckeo", disp, gate_vals.astype(jnp.float32))
    yi = jnp.einsum("ckeo,eod->cd", comb, ye.astype(jnp.float32))
    return yi.astype(xi.dtype)


def _chunk_gather(xi, probs, p, *, top_k, e, cap, chunk):
    """Sort-based FIFO dispatch (the BFS queue-crossbar mechanism)."""
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # [c, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    ck = chunk * top_k
    flat_e = gate_idx.reshape(-1)                           # [c*k]
    order = jnp.argsort(flat_e)                             # stable
    sorted_e = flat_e[order]
    sorted_tok = order // top_k
    # rank within each expert's queue; searchsorted = queue head offsets
    start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(ck, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    fits = rank < cap
    slot = jnp.where(fits, sorted_e * cap + rank, e * cap)  # drop overflow
    # dispatch: scatter token rows into the [e*cap, d] expert buffers
    xe = jnp.zeros((e * cap + 1, xi.shape[1]), xi.dtype)
    xe = xe.at[slot].set(xi[sorted_tok], mode="drop")[:-1]
    xe = psh.constrain(xe.reshape(e, cap, -1), "experts", None, None)
    ye = _expert_ffn(xe, p, xi.dtype)
    # combine: gather each surviving slot's output back to its token
    contrib = ye.reshape(e * cap, -1)[jnp.minimum(slot, e * cap - 1)]
    w = jnp.where(fits, gate_vals.reshape(-1)[order], 0.0)
    yi = jnp.zeros_like(xi, shape=(chunk, xi.shape[1]))
    yi = yi.at[sorted_tok].add(contrib * w[:, None].astype(contrib.dtype))
    return yi


def _chunk_gather_local(xi, probs, wg, wu, wd, *, top_k, e, el, r, cap,
                        chunk):
    """Per-rank FIFO dispatch: this rank owns experts [r*el, (r+1)*el).

    Queue positions are computed over the FULL expert id space (identical
    on every rank), so the capacity-drop set matches the single-engine
    semantics exactly; only the local experts' slots are then materialized
    and processed."""
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # [c, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    ck = chunk * top_k
    flat_e = gate_idx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    sorted_tok = order // top_k
    start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(ck, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    local_e = sorted_e - r * el
    mine = (local_e >= 0) & (local_e < el) & (rank < cap)
    slot = jnp.where(mine, local_e * cap + rank, el * cap)
    xe = jnp.zeros((el * cap + 1, xi.shape[1]), xi.dtype)
    xe = xe.at[slot].set(xi[sorted_tok], mode="drop")[:-1]
    xe = xe.reshape(el, cap, -1)
    g = jax.nn.silu(jnp.einsum("eod,edf->eof", xe, wg).astype(jnp.float32))
    u = jnp.einsum("eod,edf->eof", xe, wu).astype(jnp.float32)
    ye = jnp.einsum("eof,efd->eod", (g * u).astype(xi.dtype), wd)
    contrib = ye.reshape(el * cap, -1)[jnp.minimum(slot, el * cap - 1)]
    w = jnp.where(mine, gate_vals.reshape(-1)[order], 0.0)
    yi = jnp.zeros_like(xi, shape=(chunk, xi.shape[1]))
    # combine in the activation dtype: the f32 [c*k, d] intermediate was
    # ~40% of the chunk body's HBM bytes (EXPERIMENTS.md §Perf iter 3)
    yi = yi.at[sorted_tok].add(contrib * w[:, None].astype(contrib.dtype))
    return yi   # partial: local experts only; caller psums over the EP axis


def _moe_forward_ep(x: jax.Array, p: dict, mesh, *, top_k: int,
                    capacity_factor: float, chunk: int):
    """shard_map expert parallelism — the paper's queue crossbar as an MoE
    dispatcher.  Tokens are batch-sharded over (pod, data) and replicated
    over `model`; each model-rank routes the (locally visible) tokens to
    its own expert block and a single psum combines partial outputs.
    Collective cost: one [tb, s, d] all-reduce per MoE layer."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    axes = mesh.axis_names
    ep_axis = "model"
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    b, s, d = x.shape
    e = p["router"].shape[1]
    tp = mesh.shape[ep_axis]
    el = e // tp

    def body(xb, router, wg, wu, wd):
        r = jax.lax.axis_index(ep_axis)
        tb = xb.shape[0]
        xt = xb.reshape(tb * s, d)
        t = xt.shape[0]
        c = min(chunk, t)
        pad = (-t) % c
        if pad:
            xt = jnp.pad(xt, ((0, pad), (0, 0)))
        nchunk = xt.shape[0] // c
        xc = xt.reshape(nchunk, c, d)
        cap = max(int(c * top_k / e * capacity_factor), 4)
        logits_all = jnp.einsum("ntd,de->nte", xc.astype(jnp.float32),
                                router)
        probs_all = jax.nn.softmax(logits_all, axis=-1)

        def one_chunk(carry, inp):
            xi, probs = inp
            yi = _chunk_gather_local(xi, probs, wg, wu, wd, top_k=top_k,
                                     e=e, el=el, r=r, cap=cap, chunk=c)
            return carry, yi

        _, yc = jax.lax.scan(one_chunk, None, (xc, probs_all))
        y = yc.reshape(-1, d)[: t].reshape(tb, s, d)
        y = jax.lax.psum(y, ep_axis)              # combine expert partials
        me = probs_all.mean((0, 1))
        top1 = jax.nn.one_hot(jnp.argmax(logits_all, -1), e).mean((0, 1))
        if dp_axes:
            # the Switch loss is nonlinear in the partition: average the
            # per-expert fractions globally BEFORE taking the product
            me = jax.lax.pmean(me, dp_axes)
            top1 = jax.lax.pmean(top1, dp_axes)
        aux = e * jnp.sum(me * top1)
        return y, aux

    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    xs = P(dp, None, None)
    es = P(ep_axis, None, None)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(xs, P(), es, es, es),
        out_specs=(xs, P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux


def _ep_applicable(mesh, b, e) -> bool:
    if mesh is None or not mesh.axis_names or "model" not in mesh.axis_names:
        return False
    import math
    tp = mesh.shape["model"]
    dp = math.prod(mesh.shape[a] for a in ("pod", "data")
                   if a in mesh.axis_names)
    return tp > 1 and e % tp == 0 and b % dp == 0


def moe_forward(x: jax.Array, p: dict, *, top_k: int,
                capacity_factor: float = 1.25, chunk: int = 1024,
                dispatch: str = "gather") -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    if dispatch == "ep":
        from repro import compat
        mesh = compat.get_abstract_mesh()
        if _ep_applicable(mesh, x.shape[0], p["router"].shape[1]):
            return _moe_forward_ep(x, p, mesh, top_k=top_k,
                                   capacity_factor=capacity_factor,
                                   chunk=chunk)
        dispatch = "gather"   # single-device / misaligned fallback
    b, s, d = x.shape
    e = p["router"].shape[1]
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    nchunk = xt.shape[0] // chunk
    xc = xt.reshape(nchunk, chunk, d)
    cap = max(int(chunk * top_k / e * capacity_factor), 4)

    logits_all = jnp.einsum("ntd,de->nte", xc.astype(jnp.float32),
                            p["router"])
    probs_all = jax.nn.softmax(logits_all, axis=-1)
    chunk_fn = _chunk_gather if dispatch == "gather" else _chunk_onehot

    def one_chunk(carry, inp):
        xi, probs = inp
        yi = chunk_fn(xi, probs, p, top_k=top_k, e=e, cap=cap, chunk=chunk)
        return carry, yi

    _, yc = jax.lax.scan(one_chunk, None, (xc, probs_all))
    y = yc.reshape(-1, d)[: t].reshape(b, s, d)
    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = probs_all.mean((0, 1))
    top1 = jax.nn.one_hot(jnp.argmax(logits_all, -1), e).mean((0, 1))
    aux = e * jnp.sum(me * top1)
    return y, aux
