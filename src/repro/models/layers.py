"""Shared layer primitives: norms, RoPE, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import psharding as psh


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., seq, heads, head_dim]; positions [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def mlp_forward(x: jax.Array, p: dict, act: str) -> jax.Array:
    hint = ("batch",) + (None,) * (x.ndim - 2) + ("ff",)
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:  # gelu
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = psh.constrain(h, *hint)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def mlp_params(key, d: int, f: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / float(np.sqrt(d))
    scale_out = 1.0 / float(np.sqrt(f))
    p = {"w_up": jax.random.normal(k2, (d, f), dtype) * scale_in,
         "w_down": jax.random.normal(k3, (f, d), dtype) * scale_out}
    if act == "swiglu":
        p["w_gate"] = jax.random.normal(k1, (d, f), dtype) * scale_in
    return p


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None,
                       valid_vocab: int | None = None) -> jax.Array:
    """NLL over (possibly vocab-padded) logits; padded columns masked."""
    logits = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < valid_vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
