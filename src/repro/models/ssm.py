"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Scalar-identity state transition per head: h_t = a_t * h_{t-1} + dt_t * B_t x_t,
y_t = C_t h_t + D x_t, with a_t = exp(-dt_t * exp(A_log)).  Training uses the
SSD chunked decomposition (quadratic only within a chunk, O(hd*N) state
carried across chunks); decode is the single-step recurrence over a cached
state.

Shapes: d_inner = expand * d_model, heads = d_inner / head_dim,
state = ssm_state (N).  Conv1d width-4 over the x/B/C streams (cached for
decode).  Grouped B/C (single group, multi-head share B/C as in Mamba-2).

TP note: the input projection is stored as one weight per stream
(w_z / w_xin / w_b / w_c / w_dt) rather than Mamba's packed in_proj, so
each stream's output dim carries its own TP sharding.  A packed projection
sliced across a model-sharded channel dim costs ~80 collective-permutes
per layer in halo resharding (measured in the 512-device dry-run); the
split form is mathematically identical and alignment-clean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import psharding as psh


def ssm_params(key, d: int, expand: int, head_dim: int, state: int,
               conv_width: int, dtype) -> dict:
    di = expand * d
    nh = di // head_dim
    ks = jax.random.split(key, 9)
    s = 1.0 / float(np.sqrt(d))
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dtype) * s,
        "w_xin": jax.random.normal(ks[1], (d, di), dtype) * s,
        "w_b": jax.random.normal(ks[2], (d, state), dtype) * s,
        "w_c": jax.random.normal(ks[3], (d, state), dtype) * s,
        "w_dt": jax.random.normal(ks[4], (d, nh), dtype) * s,
        "conv_wx": jax.random.normal(ks[5], (conv_width, di), dtype) * 0.5,
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_wb": jax.random.normal(ks[6], (conv_width, state), dtype) * 0.5,
        "conv_bb": jnp.zeros((state,), dtype),
        "conv_wc": jax.random.normal(ks[7], (conv_width, state), dtype) * 0.5,
        "conv_bc": jnp.zeros((state,), dtype),
        "a_log": jnp.asarray(
            np.log(np.random.default_rng(0).uniform(1, 16, nh)),
            jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.random.default_rng(1).uniform(1e-3, 0.1, nh))),
            jnp.float32),
        "out_proj": jax.random.normal(ks[8], (di, d), dtype) / float(np.sqrt(di)),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d + SiLU.  u: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(up[:, i: i + u.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(u.dtype)


def ssm_forward(x_in: jax.Array, p: dict, *, expand: int, head_dim: int,
                state: int, chunk: int = 256) -> jax.Array:
    """x_in: [B, S, d] -> [B, S, d] (training / prefill path).

    SSD chunked decomposition [arXiv:2405.21060 §6]: within a chunk the
    recurrence is evaluated in its "attention" dual form (an L x L masked
    score matrix per head); across chunks only the [nh, hd, N]
    end-of-chunk state is carried.  Peak intermediate is
    O(B * chunk^2 * nh) instead of the O(B * S * nh * hd * N)
    per-position state history (68 GB/device at the train_4k cell -- the
    512-device dry-run caught the naive version)."""
    b, s, d = x_in.shape
    di = expand * d
    nh = di // head_dim
    z = psh.constrain(jnp.einsum("bsd,dp->bsp", x_in, p["w_z"]),
                      "batch", None, "ff")
    xs = psh.constrain(jnp.einsum("bsd,dp->bsp", x_in, p["w_xin"]),
                       "batch", None, "ff")
    bm = jnp.einsum("bsd,dn->bsn", x_in, p["w_b"])
    cm = jnp.einsum("bsd,dn->bsn", x_in, p["w_c"])
    dt = psh.constrain(jnp.einsum("bsd,dh->bsh", x_in, p["w_dt"]),
                       "batch", None, "heads")
    xs = _causal_conv(xs, p["conv_wx"], p["conv_bx"])
    bm = _causal_conv(bm, p["conv_wb"], p["conv_bb"])
    cm = _causal_conv(cm, p["conv_wc"], p["conv_bc"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                     # [B,S,nh]
    la = -dt * jnp.exp(p["a_log"])                           # log a_t <= 0
    xh = xs.reshape(b, s, nh, head_dim)
    xh = psh.constrain(xh, "batch", None, "heads", None)
    xh32 = xh.astype(jnp.float32)
    dtx = dt[..., None] * xh32                               # [B,S,nh,hd]

    pad = (-s) % chunk
    if pad:
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        dtx = jnp.pad(dtx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    c = chunk
    nc = la.shape[1] // c
    lac = la.reshape(b, nc, c, nh).transpose(1, 0, 2, 3)
    dtxc = dtx.reshape(b, nc, c, nh, head_dim).transpose(1, 0, 2, 3, 4)
    bmc = bm.astype(jnp.float32).reshape(b, nc, c, state).transpose(
        1, 0, 2, 3)
    cmc = cm.astype(jnp.float32).reshape(b, nc, c, state).transpose(
        1, 0, 2, 3)
    tril = jnp.tril(jnp.ones((c, c), jnp.bool_))

    def chunk_step(h_prev, inp):
        lai, dtxi, bi, ci = inp  # [B,c,nh] [B,c,nh,hd] [B,c,N] [B,c,N]
        cum = jnp.cumsum(lai, axis=1)                        # inclusive
        # y_diag[t] = sum_{s<=t} exp(cum_t - cum_s) (C_t . B_s) dtx_s
        scores = jnp.einsum("btn,bsn->bts", ci, bi)          # [B,c,c]
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B,t,s,nh]
        w = scores[..., None] * jnp.where(tril[None, :, :, None], dec, 0.0)
        y_diag = jnp.einsum("btsh,bshd->bthd", w, dtxi)
        # y_off[t] = exp(cum_t) * (C_t . h_prev)
        y_off = jnp.exp(cum)[..., None] * jnp.einsum(
            "btn,bhdn->bthd", ci, h_prev)
        # end-of-chunk state: exp(cum_last) h_prev + decayed outer products
        sdec = jnp.exp(cum[:, -1:, :] - cum)                 # [B,c,nh]
        s_c = jnp.einsum("bsh,bshd,bsn->bhdn", sdec, dtxi, bi)
        h_new = jnp.exp(cum[:, -1])[..., None, None] * h_prev + s_c
        return h_new, y_diag + y_off

    h0 = jnp.zeros((b, nh, head_dim, state), jnp.float32)
    h0 = psh.constrain(h0, "batch", "heads", None, None)
    _, ys = jax.lax.scan(chunk_step, h0, (lac, dtxc, bmc, cmc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, -1, nh, head_dim)[:, :s]
    y = y + xh32 * p["d_skip"][:, None]
    y = y.reshape(b, s, di).astype(x_in.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x_in.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def ssm_init_cache(batch: int, d: int, expand: int, head_dim: int,
                   state: int, conv_width: int, dtype):
    di = expand * d
    nh = di // head_dim
    return {
        "conv_x": jnp.zeros((batch, conv_width - 1, di), dtype),
        "conv_b": jnp.zeros((batch, conv_width - 1, state), dtype),
        "conv_c": jnp.zeros((batch, conv_width - 1, state), dtype),
        "h": jnp.zeros((batch, nh, head_dim, state), jnp.float32),
    }


def _conv_step(hist: jax.Array, new: jax.Array, w: jax.Array, b: jax.Array):
    """One-token depthwise conv against a [B, K-1, C] history window."""
    window = jnp.concatenate([hist, new[:, None]], axis=1)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return (jax.nn.silu(out.astype(jnp.float32)).astype(new.dtype),
            window[:, 1:])


def ssm_decode(x_in: jax.Array, p: dict, cache: dict, *, expand: int,
               head_dim: int, state: int):
    """One-token decode.  x_in: [B, 1, d]."""
    b, _, d = x_in.shape
    di = expand * d
    nh = di // head_dim
    x0 = x_in[:, 0]
    z = jnp.einsum("bd,dp->bp", x0, p["w_z"])
    xs = jnp.einsum("bd,dp->bp", x0, p["w_xin"])
    bm = jnp.einsum("bd,dn->bn", x0, p["w_b"])
    cm = jnp.einsum("bd,dn->bn", x0, p["w_c"])
    dt = jnp.einsum("bd,dh->bh", x0, p["w_dt"])
    xs, conv_x = _conv_step(cache["conv_x"], xs, p["conv_wx"], p["conv_bx"])
    bm, conv_b = _conv_step(cache["conv_b"], bm, p["conv_wb"], p["conv_bb"])
    cm, conv_c = _conv_step(cache["conv_c"], cm, p["conv_wc"], p["conv_bc"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-dt * jnp.exp(p["a_log"]))                   # [B, nh]
    xh = xs.reshape(b, nh, head_dim).astype(jnp.float32)
    h = (cache["h"] * a[..., None, None]
         + jnp.einsum("bh,bn,bhd->bhdn", dt, bm.astype(jnp.float32), xh))
    y = jnp.einsum("bhdn,bn->bhd", h, cm.astype(jnp.float32))
    y = y + xh * p["d_skip"][:, None]
    y = y.reshape(b, di).astype(x_in.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x_in.dtype)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None]
    new_cache = {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c,
                 "h": h}
    return out, new_cache
