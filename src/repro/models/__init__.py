from repro.models.config import ArchConfig, layer_plan_kinds, layer_segments
from repro.models.transformer import (abstract_params, forward_train,
                                      init_decode_state, init_params,
                                      loss_fn, serve_step)

__all__ = [
    "ArchConfig", "layer_plan_kinds", "layer_segments", "abstract_params",
    "forward_train", "init_decode_state", "init_params", "loss_fn",
    "serve_step",
]
