"""Segment-structured transformer: init / train forward / decode step.

The layer stack is run-length-encoded into segments of identical layer kind
(config.layer_segments).  Each segment executes as one `lax.scan` over its
stacked parameters with per-layer remat — HLO size stays O(#segments)
regardless of depth, which is what makes 512-device dry-run compiles of
34B-60L models tractable.  Roofline accounting multiplies each scan body's
cost by its trip count (launch/roofline.py), since XLA's cost_analysis
counts while-loop bodies once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import psharding as psh
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig, layer_segments
from repro.models.layers import (cross_entropy_loss, layer_norm, mlp_forward,
                                 mlp_params, rms_norm, sinusoidal_positions)

LOCAL_WINDOW_DEFAULT = 1024


def _window_for(cfg: ArchConfig, kind: str) -> int:
    if kind == "attn_local":
        return cfg.window or LOCAL_WINDOW_DEFAULT
    if kind == "attn" and cfg.window:
        return cfg.window
    return 0


# ---------------------------------------------------------------------------
# Parameter init (pure; use jax.eval_shape for abstract init)
# ---------------------------------------------------------------------------

def _one_layer_params(kind: str, key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p: dict = {}
    if kind in ("attn", "attn_local", "attn_global", "moe", "enc", "dec"):
        p["ln1"] = jnp.zeros((d,), jnp.float32)
        p["attn"] = attn.attn_params(ks[0], d, h, hkv, hd, dtype)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        if kind == "moe":
            p["moe"] = moe_mod.moe_params(ks[1], d, f, cfg.num_experts, dtype)
        else:
            p["mlp"] = mlp_params(ks[1], d, f, cfg.mlp_act, dtype)
        if kind == "dec":
            p["ln_x"] = jnp.zeros((d,), jnp.float32)
            p["xattn"] = attn.attn_params(ks[2], d, h, hkv, hd, dtype)
        if kind in ("enc", "dec"):   # whisper uses LayerNorm biases
            p["ln1_b"] = jnp.zeros((d,), jnp.float32)
            p["ln2_b"] = jnp.zeros((d,), jnp.float32)
            if kind == "dec":
                p["ln_x_b"] = jnp.zeros((d,), jnp.float32)
    elif kind == "ssm":
        p["ln1"] = jnp.zeros((d,), jnp.float32)
        p["ssm"] = ssm_mod.ssm_params(ks[0], d, cfg.ssm_expand,
                                      cfg.ssm_head_dim, cfg.ssm_state,
                                      cfg.ssm_conv_width, dtype)
    elif kind == "rglru":
        p["ln1"] = jnp.zeros((d,), jnp.float32)
        p["rglru"] = rglru_mod.rglru_params(ks[0], d, cfg.lru_width, 4, dtype)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = mlp_params(ks[1], d, f, cfg.mlp_act, dtype)
    else:
        raise ValueError(kind)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    keys = jax.random.split(key, len(layer_segments(cfg)) + 2)
    segs = []
    for i, (kind, count) in enumerate(layer_segments(cfg)):
        lk = jax.random.split(keys[i], count)
        stacked = jax.vmap(
            lambda k: _one_layer_params(kind, k, cfg, dtype))(lk)
        segs.append(stacked)
    params = {
        "embed": jax.random.normal(keys[-2], (cfg.vocab_padded, cfg.d_model),
                                   dtype) * 0.02,
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "segments": segs,
    }
    if cfg.encoder_layers:
        params["enc_final_ln"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype),
        jax.random.key(0))


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------

def _apply_layer_train(kind: str, p: dict, x, positions, cfg: ArchConfig,
                       enc_out=None):
    eps = cfg.norm_eps
    if kind in ("enc", "dec"):
        h = layer_norm(x, 1.0 + p["ln1"], p["ln1_b"], eps)
    else:
        h = rms_norm(x, p["ln1"], eps)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local", "attn_global", "moe", "enc", "dec"):
        causal = kind != "enc"
        theta = 0.0 if kind in ("enc", "dec") else cfg.rope_theta
        x = x + attn.attention_block(
            h, p["attn"], positions=positions, causal=causal,
            window=_window_for(cfg, kind), rope_theta=theta,
            flash_threshold=cfg.flash_threshold)
        if kind == "dec":
            hx = layer_norm(x, 1.0 + p["ln_x"], p["ln_x_b"], eps)
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
            x = x + attn.attention_block(
                hx, p["xattn"], positions=positions, causal=False,
                rope_theta=0.0, kv_override=(k, v))
        if kind in ("enc", "dec"):
            h2 = layer_norm(x, 1.0 + p["ln2"], p["ln2_b"], eps)
        else:
            h2 = rms_norm(x, p["ln2"], eps)
        if kind == "moe":
            y, aux = moe_mod.moe_forward(h2, p["moe"], top_k=cfg.top_k,
                                         capacity_factor=cfg.capacity_factor,
                                         dispatch=cfg.moe_dispatch,
                                         chunk=cfg.moe_chunk)
            x = x + y
        else:
            x = x + mlp_forward(h2, p["mlp"], cfg.mlp_act)
    elif kind == "ssm":
        x = x + ssm_mod.ssm_forward(h, p["ssm"], expand=cfg.ssm_expand,
                                    head_dim=cfg.ssm_head_dim,
                                    state=cfg.ssm_state)
    elif kind == "rglru":
        x = x + rglru_mod.rglru_forward(h, p["rglru"])
        h2 = rms_norm(x, p["ln2"], eps)
        x = x + mlp_forward(h2, p["mlp"], cfg.mlp_act)
    return x, aux


def segment_train_body(kind: str, cfg: ArchConfig, remat: bool = True):
    """The per-layer scan body for a segment (exposed for roofline)."""

    def body(carry, p_i):
        x, positions, enc_out, aux = carry
        if cfg.seq_parallel:
            # Megatron-SP: the residual stream lives seq-sharded over
            # `model`; XLA turns the entries/exits of attention/MLP into
            # all-to-alls and all norm/residual elementwise work shrinks
            # by the TP degree.
            x = psh.constrain(x, "batch", "q_seq", None)
        x, a = _apply_layer_train(kind, p_i, x, positions, cfg, enc_out)
        if cfg.seq_parallel:
            x = psh.constrain(x, "batch", "q_seq", None)
        return (x, positions, enc_out, aux + a), ()

    return jax.checkpoint(body) if remat else body


def apply_segment_train(kind: str, stacked: dict, x, positions,
                        cfg: ArchConfig, enc_out=None):
    body = segment_train_body(kind, cfg, cfg.remat)
    (x, _, _, aux), _ = jax.lax.scan(
        body, (x, positions, enc_out, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


def forward_train(params: dict, cfg: ArchConfig, tokens=None, embeds=None,
                  frames=None):
    """Returns (logits [B, S, V], aux_loss)."""
    segs = layer_segments(cfg)
    if embeds is not None:
        x = embeds                       # vlm stub: precomputed embeddings
    else:
        x = params["embed"][tokens]
    x = psh.constrain(x, "batch", None, None)
    b, s, d = x.shape
    positions = jnp.arange(s)
    aux_total = jnp.zeros((), jnp.float32)
    enc_out = None
    seg_params = params["segments"]
    idx = 0
    if cfg.encoder_layers:
        # whisper: encoder over frame embeddings with sinusoidal positions
        pe = jnp.asarray(sinusoidal_positions(frames.shape[1], d))
        xe = frames + pe.astype(frames.dtype)
        for (kind, count) in segs:
            if kind != "enc":
                break
            xe, _ = apply_segment_train(kind, seg_params[idx], xe,
                                        jnp.arange(frames.shape[1]), cfg)
            idx += 1
        enc_out = rms_norm(xe, params["enc_final_ln"], cfg.norm_eps)
        pd = jnp.asarray(sinusoidal_positions(s, d))
        x = x + pd.astype(x.dtype)
    for (kind, count) in segs[idx:]:
        x, aux = apply_segment_train(kind, seg_params[idx], x, positions,
                                     cfg, enc_out)
        aux_total = aux_total + aux
        idx += 1
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    logits = psh.constrain(logits, "batch", None, "vocab")
    return logits, aux_total


def prefill_step(params: dict, cfg: ArchConfig, batch: dict):
    """Inference prefill: full forward over the prompt, next-token logits.

    Returns logits [B, V] for the last position (the serving handoff point;
    KV-cache materialization is the decode path's ring/full caches — see
    DESIGN.md §5 for why prefill compute, not cache writes, is the roofline
    object for the prefill_32k cell)."""
    logits, _ = forward_train(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        frames=batch.get("frames"))
    return logits[:, -1]


def loss_fn(params: dict, cfg: ArchConfig, batch: dict):
    logits, aux = forward_train(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        frames=batch.get("frames"))
    loss = cross_entropy_loss(logits, batch["labels"],
                              batch.get("loss_mask"),
                              valid_vocab=cfg.vocab_size)
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, enc_len: int = 0):
    """Per-segment cache stacks.  cache_len = full KV length for global
    layers; windowed layers get a ring of min(window, cache_len)."""
    segs = layer_segments(cfg)
    caches = []
    for kind, count in segs:
        if kind in ("attn", "attn_local", "attn_global", "moe", "dec"):
            w = _window_for(cfg, kind)
            clen = min(w, cache_len) if w else cache_len
            c = {
                "k": jnp.zeros((count, batch, clen, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((count, batch, clen, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
            }
            if kind == "dec":
                c["xk"] = jnp.zeros((count, batch, enc_len, cfg.num_kv_heads,
                                     cfg.head_dim), dtype)
                c["xv"] = jnp.zeros((count, batch, enc_len, cfg.num_kv_heads,
                                     cfg.head_dim), dtype)
            caches.append(c)
        elif kind == "ssm":
            c1 = ssm_mod.ssm_init_cache(batch, cfg.d_model, cfg.ssm_expand,
                                        cfg.ssm_head_dim, cfg.ssm_state,
                                        cfg.ssm_conv_width, dtype)
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), c1))
        elif kind == "rglru":
            c1 = rglru_mod.rglru_init_cache(batch, cfg.lru_width, 4, dtype)
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), c1))
        elif kind == "enc":
            caches.append({})
    return caches


def _apply_layer_decode(kind: str, p: dict, x, cache, pos, cfg: ArchConfig):
    eps = cfg.norm_eps
    if kind == "dec":
        h = layer_norm(x, 1.0 + p["ln1"], p["ln1_b"], eps)
    else:
        h = rms_norm(x, p["ln1"], eps)
    if kind in ("attn", "attn_local", "attn_global", "moe", "dec"):
        theta = 0.0 if kind == "dec" else cfg.rope_theta
        w = _window_for(cfg, kind)
        y, kv = attn.attention_decode(h, p["attn"],
                                      {"k": cache["k"], "v": cache["v"]},
                                      pos, window=w, rope_theta=theta)
        x = x + y
        new_cache = dict(cache)
        new_cache.update(kv)
        if kind == "dec":
            hx = layer_norm(x, 1.0 + p["ln_x"], p["ln_x_b"], eps)
            o = attn.attention_block(hx, p["xattn"],
                                     positions=jnp.full((x.shape[0], 1), pos),
                                     causal=False, rope_theta=0.0,
                                     kv_override=(cache["xk"], cache["xv"]))
            x = x + o
        if kind == "dec":
            h2 = layer_norm(x, 1.0 + p["ln2"], p["ln2_b"], eps)
        else:
            h2 = rms_norm(x, p["ln2"], eps)
        if kind == "moe":
            y, _ = moe_mod.moe_forward(h2, p["moe"], top_k=cfg.top_k,
                                       capacity_factor=cfg.capacity_factor,
                                       dispatch=cfg.moe_dispatch,
                                       chunk=cfg.moe_chunk)
            x = x + y
        else:
            x = x + mlp_forward(h2, p["mlp"], cfg.mlp_act)
    elif kind == "ssm":
        y, new_cache = ssm_mod.ssm_decode(h, p["ssm"], cache,
                                          expand=cfg.ssm_expand,
                                          head_dim=cfg.ssm_head_dim,
                                          state=cfg.ssm_state)
        x = x + y
    elif kind == "rglru":
        y, new_cache = rglru_mod.rglru_decode(h, p["rglru"], cache)
        x = x + y
        h2 = rms_norm(x, p["ln2"], eps)
        x = x + mlp_forward(h2, p["mlp"], cfg.mlp_act)
    else:
        raise ValueError(kind)
    return x, new_cache


def serve_step(params: dict, cfg: ArchConfig, caches: list, tokens, pos):
    """One decode step.  tokens: int32[B]; pos: scalar position.

    Returns (logits [B, V], new caches)."""
    x = params["embed"][tokens][:, None]          # [B, 1, d]
    if cfg.encoder_layers:
        pd = jnp.asarray(sinusoidal_positions(1, cfg.d_model))
        x = x + pd.astype(x.dtype)
    new_caches = []
    idx = 0
    for seg_i, (kind, count) in enumerate(layer_segments(cfg)):
        stacked_p = params["segments"][seg_i]
        cache = caches[seg_i]
        if kind == "enc":
            new_caches.append(cache)
            continue

        def body(x, pc):
            p_i, c_i = pc
            x, c2 = _apply_layer_decode(kind, p_i, x, c_i, pos, cfg)
            return x, c2

        x, c_new = jax.lax.scan(body, x, (stacked_p, cache))
        new_caches.append(c_new)
        idx += 1
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x[:, 0:1], params["embed"])[:, 0]
    logits = psh.constrain(logits, "batch", "vocab")
    return logits[:, : cfg.vocab_size], new_caches
