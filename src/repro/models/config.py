"""Architecture configuration schema for all assigned model families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "gather"   # gather (sort-FIFO) | onehot | ep
    moe_chunk: int = 1024          # dispatch token-chunk size

    # --- attention pattern ---
    window: int = 0             # sliding-window size (0 = full attention)
    local_global_ratio: int = 0  # gemma3: N local layers then 1 global
    mlp_act: str = "swiglu"     # swiglu | gelu

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma): N recurrent blocks then 1 local attn ---
    recurrent_ratio: int = 0
    lru_width: int = 0          # 0 -> d_model

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0     # >0 => enc-dec; num_layers = decoder layers

    # --- modality frontend stub ---
    frontend: str = "none"      # none | audio_stub | vision_stub

    # --- training ---
    seq_parallel: bool = False  # Megatron-SP: residual stream seq-sharded
    flash_threshold: int = 8192  # use chunked flash attention above this S
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    remat: bool = True
    tie_embeddings: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))
        if self.recurrent_ratio and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows: vocab rounded up to 256 so the logits'
        vocab dim shards over any mesh axis (<=256-way); padded columns are
        masked to -inf in the loss / decode (production-standard)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode (500k) is feasible: every layer's
        state is bounded (SSM/RG-LRU) or windowed, or global layers are a
        small fraction (gemma3 local:global)."""
        return (self.family in ("ssm", "hybrid")
                or self.window > 0 or self.local_global_ratio > 0)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, hkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * hd * (h + 2 * hkv) + h * hd * d
        mlp_dense = 3 * d * f if self.mlp_act == "swiglu" else 2 * d * f
        n = v * d  # tied embedding
        per_layer = []
        for kind in layer_plan_kinds(self):
            if kind == "moe":
                e = self.num_experts
                per_layer.append(attn + d * e + e * 3 * d * f)
            elif kind == "ssm":
                di = self.ssm_expand * d
                nh = di // self.ssm_head_dim
                per_layer.append(d * (2 * di + 2 * self.ssm_state + nh)
                                 + di * d + 3 * di)
            elif kind == "rglru":
                w = self.lru_width
                per_layer.append(3 * d * w + 2 * w * w + mlp_dense)
            elif kind in ("attn", "attn_local", "attn_global", "enc", "dec"):
                x = attn + mlp_dense
                if kind == "dec":
                    x += d * hd * (h + 2 * hkv) + h * hd * d  # cross-attn
                per_layer.append(x)
        n += sum(per_layer)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f, e, k = self.d_model, self.d_ff, self.num_experts, self.top_k
        total = self.param_count()
        moe_all = self.num_layers * e * 3 * d * f
        moe_act = self.num_layers * k * 3 * d * f
        return total - moe_all + moe_act


def layer_plan_kinds(cfg: ArchConfig) -> list[str]:
    """Flat list of per-layer kinds, in execution order."""
    kinds = []
    if cfg.encoder_layers:
        kinds += ["enc"] * cfg.encoder_layers + ["dec"] * cfg.num_layers
        return kinds
    for i in range(cfg.num_layers):
        if cfg.family == "ssm":
            kinds.append("ssm")
        elif cfg.recurrent_ratio:
            # recurrentgemma: (recurrent_ratio) RG-LRU blocks, then 1 local attn
            kinds.append("attn_local" if i % (cfg.recurrent_ratio + 1)
                         == cfg.recurrent_ratio else "rglru")
        elif cfg.local_global_ratio:
            # gemma3: N local (SWA) layers then 1 global
            kinds.append("attn_global" if i % (cfg.local_global_ratio + 1)
                         == cfg.local_global_ratio else "attn_local")
        elif cfg.is_moe:
            kinds.append("moe")
        else:
            kinds.append("attn")
    return kinds


def layer_segments(cfg: ArchConfig) -> list[tuple[str, int]]:
    """Run-length-encoded layer plan: [(kind, count), ...].  Each segment is
    executed as one `lax.scan` over its stacked params (bounded HLO size)."""
    kinds = layer_plan_kinds(cfg)
    segs: list[tuple[str, int]] = []
    for k in kinds:
        if segs and segs[-1][0] == k:
            segs[-1] = (k, segs[-1][1] + 1)
        else:
            segs.append((k, 1))
    return segs
