from repro.graph.csr import (CSRGraph, csr_from_edges, symmetrize_csr,
                             symmetrize_edges, transpose_csr)
from repro.graph.generators import rmat_edges, uniform_edges
from repro.graph.datasets import get_dataset, DATASETS

__all__ = [
    "CSRGraph", "csr_from_edges", "transpose_csr", "symmetrize_edges",
    "symmetrize_csr", "rmat_edges", "uniform_edges", "get_dataset",
    "DATASETS",
]
