"""Named dataset registry mirroring the paper's Table I.

Real-world SNAP/LAW graphs are not downloadable in this offline container, so
the registry exposes the paper's full RMAT suite (exact scales/degrees) plus
reduced stand-ins for the four real-world graphs with matched vertex-count /
average-degree *ratios* (documented in EXPERIMENTS.md).  Every entry is
generated deterministically and cached on disk.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.graph.csr import CSRGraph, csr_from_edges, symmetrize_edges, transpose_csr
from repro.graph.generators import rmat_edges

CACHE_DIR = os.environ.get("REPRO_GRAPH_CACHE", "/tmp/repro_graphs")


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    scale: int
    edge_factor: int
    directed: bool
    note: str = ""


# The paper's RMAT suite (Table I). Scales >20 are generated lazily; CPU tests
# use the 18-scale family.  Real-world stand-ins: scaled-down RMATs with the
# same average degree (PK~18.75 -> ef 19 etc.).
DATASETS = {
    # paper's synthetic suite
    "rmat18-8": DatasetSpec("rmat18-8", 18, 8, False),
    "rmat18-16": DatasetSpec("rmat18-16", 18, 16, False),
    "rmat18-32": DatasetSpec("rmat18-32", 18, 32, False),
    "rmat18-64": DatasetSpec("rmat18-64", 18, 64, False),
    "rmat20-16": DatasetSpec("rmat20-16", 20, 16, False),
    "rmat22-16": DatasetSpec("rmat22-16", 22, 16, False),
    "rmat22-32": DatasetSpec("rmat22-32", 22, 32, False),
    "rmat22-64": DatasetSpec("rmat22-64", 22, 64, False),
    "rmat23-16": DatasetSpec("rmat23-16", 23, 16, False),
    "rmat23-32": DatasetSpec("rmat23-32", 23, 32, False),
    "rmat23-64": DatasetSpec("rmat23-64", 23, 64, False),
    # real-world stand-ins (offline container; same avg-degree class)
    "pk-like": DatasetSpec("pk-like", 17, 19, True,
                           "soc-Pokec stand-in: directed, avg deg ~18.75"),
    "lj-like": DatasetSpec("lj-like", 18, 14, True,
                           "soc-LiveJournal stand-in: directed, avg deg ~14.23"),
    "or-like": DatasetSpec("or-like", 16, 76, False,
                           "com-Orkut stand-in: undirected, avg deg ~76.28"),
    "ho-like": DatasetSpec("ho-like", 15, 100, False,
                           "hollywood-2009 stand-in: undirected, avg deg ~99.91"),
    # mid-size graphs for CPU-scale throughput benchmarks (MS-BFS batching)
    "rmat14-8": DatasetSpec("rmat14-8", 14, 8, False),
    "rmat16-16": DatasetSpec("rmat16-16", 16, 16, False),
    # tiny graphs for unit tests
    "tiny-16-4": DatasetSpec("tiny-16-4", 4, 4, False),
    "small-12-8": DatasetSpec("small-12-8", 12, 8, False),
}


@dataclasses.dataclass(frozen=True)
class Dataset:
    spec: DatasetSpec
    csr: CSRGraph   # outgoing neighbor lists (push)
    csc: CSRGraph   # incoming neighbor lists (pull)


def get_dataset(name: str, seed: int = 1, cache: bool = True) -> Dataset:
    spec = DATASETS[name]
    path = os.path.join(CACHE_DIR, f"{name}-s{seed}.npz")
    if cache and os.path.exists(path):
        z = np.load(path)
        csr = CSRGraph(int(z["n"]), z["indptr"], z["indices"])
        csc = CSRGraph(int(z["n"]), z["t_indptr"], z["t_indices"])
        return Dataset(spec, csr, csc)
    src, dst = rmat_edges(spec.scale, spec.edge_factor, seed=seed)
    if not spec.directed:
        src, dst = symmetrize_edges(src, dst)
    n = 1 << spec.scale
    csr = csr_from_edges(src, dst, n)
    csc = transpose_csr(csr)
    if cache:
        os.makedirs(CACHE_DIR, exist_ok=True)
        np.savez_compressed(path, n=n, indptr=csr.indptr, indices=csr.indices,
                            t_indptr=csc.indptr, t_indices=csc.indices)
    return Dataset(spec, csr, csc)
