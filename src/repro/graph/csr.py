"""CSR/CSC graph representation (paper §II-C).

ScalaBFS keeps the immutable graph structure in CSR (outgoing / child
neighbor lists, used by push mode) and CSC (incoming / parent neighbor
lists, used by pull mode).  Construction is host-side numpy; the arrays are
handed to JAX as device buffers afterwards.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed sparse row adjacency.

    indptr:  int64[num_vertices + 1] — offset array (paper's "offset array").
    indices: int32[num_edges]        — concatenated neighbor lists ("edge array").
    """

    num_vertices: int
    indptr: np.ndarray
    indices: np.ndarray

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]: self.indptr[v + 1]]


def csr_from_edges(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                   dedup: bool = True, drop_self_loops: bool = True) -> CSRGraph:
    """Build CSR from an edge list (src -> dst)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if drop_self_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    if dedup and src.size:
        key = src * num_vertices + dst
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(num_vertices=num_vertices, indptr=indptr,
                    indices=dst.astype(np.int32))


def transpose_csr(g: CSRGraph) -> CSRGraph:
    """CSC of g == CSR of the reversed edge list."""
    src = np.repeat(np.arange(g.num_vertices, dtype=np.int64), g.degrees())
    dst = g.indices.astype(np.int64)
    return csr_from_edges(dst, src, g.num_vertices, dedup=False,
                          drop_self_loops=False)


def symmetrize_edges(src: np.ndarray, dst: np.ndarray):
    """Undirected -> directed: each edge becomes two opposite arcs (paper §VI-A)."""
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def symmetrize_csr(g: CSRGraph) -> CSRGraph:
    """Undirected view of a (possibly directed) CSR: every arc gains its
    reverse, duplicates collapse, self-loops drop (``csr_from_edges``
    defaults).  The result is its own transpose, which is what the
    connected-components engine builds on (components are an undirected
    notion — flood fill over a directed graph would compute reachability
    instead)."""
    src = np.repeat(np.arange(g.num_vertices, dtype=np.int64), g.degrees())
    dst = g.indices.astype(np.int64)
    s, d = symmetrize_edges(src, dst)
    return csr_from_edges(s, d, g.num_vertices)


def edge_sources(g: CSRGraph) -> np.ndarray:
    """Per-edge source vertex (src_of_edge[e])."""
    return np.repeat(np.arange(g.num_vertices, dtype=np.int32),
                     g.degrees()).astype(np.int32)
