"""Synthetic graph generators.

RMAT / Kronecker generator with Graph500 parameters (A=0.57, B=0.19,
C=0.19), matching the paper's synthetic workload suite ("RMAT<scale>-<deg>").
"""
from __future__ import annotations

import numpy as np


def rmat_edges(scale: int, edge_factor: int, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               permute: bool = True):
    """Graph500 Kronecker edge generator.

    Returns (src, dst) int64 arrays with ``edge_factor * 2**scale`` edges over
    ``2**scale`` vertices.  Vertex IDs are randomly permuted (Graph500 spec)
    so that degree is decorrelated from ID — this also exercises the paper's
    hash-partition load balancing.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    d = 1.0 - a - b - c
    ab = a + b
    p_dst1_given_src0 = b / ab          # quadrant B within row (A|B)
    p_dst1_given_src1 = d / (c + d)     # quadrant D within row (C|D)
    for bit in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 > ab               # P(src_bit=1) = c + d
        dst_bit = r2 < np.where(src_bit, p_dst1_given_src1, p_dst1_given_src0)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    if permute:
        perm = rng.permutation(n)
        src, dst = perm[src], perm[dst]
    return src, dst


def uniform_edges(num_vertices: int, num_edges: int, seed: int = 0):
    """Erdos-Renyi-ish uniform random edges."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    dst = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    return src, dst
