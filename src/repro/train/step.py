"""Train / serve step builders with mesh shardings.

``build_train_step`` returns a jitted (state, batch) -> (state, metrics)
with param/optimizer shardings from launch.shardings; ``build_serve_step``
returns a jitted (params, caches, tokens, pos) -> (logits, caches).
These are the functions the dry-run lowers for every (arch x shape x mesh).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import use_mesh
from repro.launch import shardings as sh
from repro.models.config import ArchConfig
from repro.models.transformer import loss_fn, prefill_step, serve_step
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    grad_compress: bool = False
    # gradient-accumulation microbatches: bounds the live activation set to
    # one microbatch (the per-device HBM-fit knob at 4k x 256 batches)
    microbatches: int = 1


def train_step_fn(cfg: ArchConfig, tcfg: TrainConfig, state: dict,
                  batch: dict):
    params = state["params"]

    def loss_of(p, b):
        loss, metrics = loss_fn(p, cfg, b)
        return loss, metrics

    nm = tcfg.microbatches
    if nm > 1:
        from repro.models import psharding as psh

        def micro_split(x):
            return x.reshape(nm, x.shape[0] // nm, *x.shape[1:])

        mb_stack = jax.tree.map(micro_split, batch)

        def micro_step(gsum, mb):
            # re-pin the microbatch's batch dim to the data axes
            mb = jax.tree.map(
                lambda x: psh.constrain(x, "batch"), mb)
            (loss, metrics), g = jax.value_and_grad(
                loss_of, has_aux=True)(params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return gsum, (loss, metrics)

        gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        gsum, (losses, metrics_all) = jax.lax.scan(micro_step, gzero,
                                                   mb_stack)
        grads = jax.tree.map(lambda g: g / nm, gsum)
        loss = losses.mean()
        metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
    else:
        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params, batch)
    if tcfg.grad_compress:
        from repro.train import compress
        key = jax.random.fold_in(jax.random.key(0), state["opt"]["step"])
        q, s = compress.compress_tree(grads, key)
        grads = compress.decompress_tree(q, s)
    new_params, new_opt, opt_metrics = adamw.apply_updates(
        params, grads, state["opt"], tcfg.optimizer)
    metrics = dict(metrics, **opt_metrics, total_loss=loss)
    return {"params": new_params, "opt": new_opt}, metrics


def init_train_state(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    from repro.models.transformer import init_params
    params = init_params(cfg, key, dtype)
    return {"params": params, "opt": adamw.init_state(params)}


def abstract_train_state(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, dtype=dtype),
        jax.random.key(0))


def state_shardings(abstract_state, mesh):
    """Params + optimizer m/v share specs; step is replicated."""
    p_sh = sh.param_shardings(abstract_state["params"], mesh)
    return {
        "params": p_sh,
        "opt": {
            "m": sh.param_shardings(abstract_state["opt"]["m"], mesh),
            "v": sh.param_shardings(abstract_state["opt"]["v"], mesh),
            "step": NamedSharding(mesh, P()),
        },
    }


def build_train_step(cfg: ArchConfig, mesh, tcfg: TrainConfig | None = None,
                     abstract_state=None, abstract_batch=None):
    """Returns (jitted_fn, state_shardings, batch_shardings)."""
    tcfg = tcfg or TrainConfig()
    abstract_state = abstract_state or abstract_train_state(cfg)
    st_sh = state_shardings(abstract_state, mesh)
    b_sh = (sh.batch_shardings(abstract_batch, mesh)
            if abstract_batch is not None else None)

    def wrapped(state, batch):
        # ambient mesh at trace time -> psharding.constrain hints apply
        with use_mesh(mesh):
            return train_step_fn(cfg, tcfg, state, batch)

    fn = jax.jit(
        wrapped,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,))
    return fn, st_sh, b_sh


def build_prefill_step(cfg: ArchConfig, mesh, abstract_params=None,
                       abstract_batch=None):
    """Returns (jitted_fn, param_shardings, batch_shardings)."""
    p_sh = sh.param_shardings(abstract_params, mesh)
    b_sh = (sh.batch_shardings(abstract_batch, mesh)
            if abstract_batch is not None else None)
    def wrapped(params, batch):
        with use_mesh(mesh):
            return prefill_step(params, cfg, batch)

    jfn = jax.jit(wrapped, in_shardings=(p_sh, b_sh))
    return jfn, p_sh, b_sh


def build_serve_step(cfg: ArchConfig, mesh, abstract_params=None,
                     abstract_caches=None, abstract_tokens=None,
                     seq_axis_joint: bool = False):
    """Returns (jitted_fn, param_shardings, cache_shardings)."""
    p_sh = sh.param_shardings(abstract_params, mesh)
    c_sh = sh.cache_shardings(abstract_caches, mesh,
                              seq_axis_joint=seq_axis_joint)
    tok_shape = (abstract_tokens.shape if abstract_tokens is not None
                 else (1,))
    tok_sh = NamedSharding(mesh, sh.batch_pspec(tok_shape, dict(mesh.shape)))

    def fn(params, caches, tokens, pos):
        with use_mesh(mesh):
            return serve_step(params, cfg, caches, tokens, pos)

    jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh, None),
                  out_shardings=(None, c_sh), donate_argnums=(1,))
    return jfn, p_sh, c_sh
