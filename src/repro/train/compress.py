"""Gradient compression: int8 quantization with per-tensor scale.

Optional wrapper around the gradient tree before the (GSPMD-inserted)
all-reduce: quantize to int8 with stochastic rounding, dequantize after.
At 512 chips this cuts gradient all-reduce bytes 4x (bf16->int8 would be
2x; fp32 master grads -> int8 is 4x).  Off by default; enabled per
TrainConfig.grad_compress.  Tests bound the quantization error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    x = g.astype(jnp.float32) / scale
    noise = jax.random.uniform(key, g.shape) - 0.5
    q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, key):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [quantize(g, k) for g, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, [d for d, _ in out]), \
        jax.tree_util.tree_unflatten(treedef, [s for _, s in out])


def decompress_tree(qtree, stree):
    return jax.tree.map(dequantize, qtree, stree)
