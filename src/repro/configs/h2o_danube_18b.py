"""h2o-danube-1.8b [dense]: 24L d2560 32H (GQA kv=8) d_ff=6912 vocab=32000,
llama+mistral mix with sliding-window attention (window 4096).
[arXiv:2401.16818; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense", num_layers=24, d_model=2560,
    num_heads=32, num_kv_heads=8, d_ff=6912, vocab_size=32000,
    head_dim=80, window=4096, rope_theta=10000.0,
    # §Perf: Megatron-style sequence parallelism (EXPERIMENTS.md)
    seq_parallel=True)

REDUCED = ArchConfig(
    name="h2o-danube-reduced", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512, window=8)
