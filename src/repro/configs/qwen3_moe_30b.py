"""qwen3-moe-30b-a3b [moe]: 48L d2048 32H (GQA kv=4) d_ff=768 (per expert),
vocab=151936, MoE 128 experts top-8.  head_dim=128 (decoupled from d_model
per the Qwen3 config).  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=4, d_ff=768, vocab_size=151936,
    head_dim=128, num_experts=128, top_k=8, rope_theta=1e6,
    # optimized defaults from the §Perf hillclimb (EXPERIMENTS.md):
    # shard_map expert-parallel FIFO dispatch, 2k-token chunks
    moe_dispatch="ep", moe_chunk=2048,
    # §Perf: Megatron-style sequence parallelism (EXPERIMENTS.md)
    seq_parallel=True)

REDUCED = ArchConfig(
    name="qwen3-moe-reduced", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=48, vocab_size=512, head_dim=32,
    num_experts=8, top_k=4)
