"""Architecture registry: --arch <id> resolves here."""
from repro.configs import (gemma3_4b, h2o_danube_18b, llama3_8b, llama32_3b,
                           llava_next_34b, mamba2_370m, phi35_moe_42b,
                           qwen3_moe_30b, recurrentgemma_2b, whisper_small)
from repro.configs.scalabfs import CONFIGS as SCALABFS_CONFIGS
from repro.models.config import ArchConfig

_MODULES = {
    "llava-next-34b": llava_next_34b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b,
    "whisper-small": whisper_small,
    "mamba2-370m": mamba2_370m,
    "llama3-8b": llama3_8b,
    "h2o-danube-1.8b": h2o_danube_18b,
    "gemma3-4b": gemma3_4b,
    "llama3.2-3b": llama32_3b,
    "recurrentgemma-2b": recurrentgemma_2b,
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    return _MODULES[name].CONFIG


def get_reduced_config(name: str) -> ArchConfig:
    return _MODULES[name].REDUCED
