"""phi3.5-moe-42b-a6.6b [moe]: 32L d4096 32H (GQA kv=8) d_ff=6400 vocab=32064,
MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=6400, vocab_size=32064,
    head_dim=128, num_experts=16, top_k=2, rope_theta=10000.0,
    # §Perf: shard_map expert-parallel FIFO dispatch (EXPERIMENTS.md)
    moe_dispatch="ep", moe_chunk=2048,
    # §Perf: Megatron-style sequence parallelism (EXPERIMENTS.md)
    seq_parallel=True)

REDUCED = ArchConfig(
    name="phi3.5-moe-reduced", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=512, num_experts=4,
    top_k=2)
