"""gemma3-4b [dense]: 34L d2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5 local (sliding-window 1024) : 1 global pattern, 128k context.
head_dim=256.  [hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense", num_layers=34, d_model=2560,
    num_heads=8, num_kv_heads=4, d_ff=10240, vocab_size=262144,
    head_dim=256, local_global_ratio=5, window=1024, rope_theta=1e6,
    # §Perf: Megatron-style sequence parallelism (EXPERIMENTS.md)
    seq_parallel=True)

REDUCED = ArchConfig(
    name="gemma3-reduced", family="dense", num_layers=6, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    local_global_ratio=5, window=8)
