"""recurrentgemma-2b [hybrid]: 26L d2560 10H (GQA kv=1, MQA) d_ff=7680
vocab=256000.  Griffin pattern: 2 RG-LRU blocks : 1 local-attention block
(window 2048); lru_width=2560, head_dim=256.  [arXiv:2402.19427; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
    num_heads=10, num_kv_heads=1, d_ff=7680, vocab_size=256000,
    head_dim=256, recurrent_ratio=2, lru_width=2560, window=2048,
    rope_theta=10000.0)

REDUCED = ArchConfig(
    name="recurrentgemma-reduced", family="hybrid", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=512,
    recurrent_ratio=2, lru_width=64, window=8)
