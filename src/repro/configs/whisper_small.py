"""whisper-small [audio]: 12 encoder + 12 decoder layers, d768 12H d_ff=3072
vocab=51865.  Conv frontend STUBBED: input_specs() provides precomputed
frame embeddings.  Sinusoidal positions, LayerNorm, GELU MLP.
[arXiv:2212.04356; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
    encoder_layers=12, mlp_act="gelu", rope_theta=0.0,
    frontend="audio_stub",
    # §Perf: Megatron-style sequence parallelism (EXPERIMENTS.md)
    seq_parallel=True)

REDUCED = ArchConfig(
    name="whisper-small-reduced", family="audio", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    encoder_layers=2, mlp_act="gelu", rope_theta=0.0,
    frontend="audio_stub")
