"""ScalaBFS experiment configurations (the paper's own system).

Mirrors the paper's evaluated configurations: PC count (here: mesh devices /
graph shards), PEs per PC (vector lanes per shard program), dispatcher
flavor (full vs multi-layer crossbar), scheduler policy, and the workload
suite of Table I.
"""
from __future__ import annotations

import dataclasses

from repro.core.bfs_distributed import DistConfig
from repro.core.scheduler import SchedulerConfig


@dataclasses.dataclass(frozen=True)
class ScalaBFSConfig:
    name: str
    num_shards: int            # HBM PC analogue (devices / graph shards)
    pes_per_shard: int         # PE analogue (lanes; informs perf model)
    dispatch: str = "bitmap"   # bitmap | queue
    crossbar: str = "staged"   # staged (multi-layer) | flat (full)
    policy: str = "beamer"     # hybrid scheduler
    datasets: tuple = ("rmat18-8", "rmat18-16", "rmat18-32", "rmat18-64")

    def dist_config(self) -> DistConfig:
        return DistConfig(dispatch=self.dispatch, crossbar=self.crossbar,
                          scheduler=SchedulerConfig(policy=self.policy))


# The paper's Table II configurations, mapped to mesh shards.
CONFIGS = {
    # 16 PC / 32 PE
    "scalabfs-16pc-32pe": ScalaBFSConfig("scalabfs-16pc-32pe", 16, 2),
    # 32 PC / 32 PE
    "scalabfs-32pc-32pe": ScalaBFSConfig("scalabfs-32pc-32pe", 32, 1),
    # 32 PC / 64 PE (peak config; 3-layer 4x4 crossbar in the paper)
    "scalabfs-32pc-64pe": ScalaBFSConfig("scalabfs-32pc-64pe", 32, 2),
    # full-pod and multi-pod scaling targets for the dry-run
    "scalabfs-pod": ScalaBFSConfig("scalabfs-pod", 256, 2),
    "scalabfs-2pod": ScalaBFSConfig("scalabfs-2pod", 512, 2),
}
