"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

AnyRes tiling vision tower is a STUB per the assignment: input_specs()
provides precomputed patch embeddings ("embeds") for the backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=20480, vocab_size=64000,
    head_dim=128, frontend="vision_stub", rope_theta=5e6,
    # §Perf: Megatron-style sequence parallelism (EXPERIMENTS.md)
    seq_parallel=True)

REDUCED = ArchConfig(
    name="llava-next-34b-reduced", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
    frontend="vision_stub")
