"""mamba2-370m [ssm]: 48L d1024, attention-free, ssm_state=128 (SSD).
expand=2 -> d_inner=2048, head_dim=64 -> 32 SSD heads.
[arXiv:2405.21060; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64)

REDUCED = ArchConfig(
    name="mamba2-reduced", family="ssm", num_layers=2, d_model=64,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16)
