"""Fault tolerance: live serving policies + deterministic chaos harness.

``failures`` holds the primitives (injection schedules, retry-from-
checkpoint, straggler timing); ``integrity`` the answer-validation layer
(detect wrong answers, don't serve them); ``supervisor`` wires both
around the serving engine as the :class:`EngineSupervisor` wave policy
the dynamic batcher delegates to.
"""
from repro.ft.failures import (FailureInjector, InjectedFailure, StepTimer,
                               run_with_retries)
from repro.ft.integrity import (INTEGRITY_MODES, IntegrityConfig,
                                IntegrityError, check_level_rows,
                                check_popcount_sequence)
from repro.ft.supervisor import (DETERMINISTIC, FAULT_KINDS, TRANSIENT,
                                 EngineSupervisor, FaultPlan, FaultyEngine,
                                 KernelFault, PoisonedRoot,
                                 RequestQuarantined, RootOutcome,
                                 ServingError, SupervisedWave,
                                 WaveAbandoned, WaveTimeout, classify_fault,
                                 find_tunable_engine, is_kernel_fault,
                                 supports_budget_override)

__all__ = [
    "FailureInjector", "InjectedFailure", "StepTimer", "run_with_retries",
    "EngineSupervisor", "SupervisedWave", "RootOutcome",
    "FaultPlan", "FaultyEngine", "FAULT_KINDS",
    "ServingError", "KernelFault", "WaveTimeout", "WaveAbandoned",
    "RequestQuarantined", "PoisonedRoot",
    "TRANSIENT", "DETERMINISTIC", "classify_fault", "is_kernel_fault",
    "find_tunable_engine", "supports_budget_override",
    "INTEGRITY_MODES", "IntegrityConfig", "IntegrityError",
    "check_level_rows", "check_popcount_sequence",
]
