"""Traversal integrity: detect wrong answers, don't serve them.

ScalaBFS trusts HBM ECC and its fixed arbiter/apply/scatter pipeline to
deliver correct frontier words; this software reproduction has no such
guarantee — a corrupted plane word or a buggy kernel rung resolves
futures with silently WRONG levels, and the supervisor only catches
faults that raise.  This module closes that gap with a detector taxonomy
layered from cheapest to strongest (see ``INTEGRITY_MODES``):

1. **Device-side statvec invariants** (mode ``invariants``) — the engine
   appends one int32 residue slot to the per-level stats vector
   (``repro.core.vertex_program.SV_CHECK``): popcounts of
   ``frontier & ~seen`` and of dirty pad bits, which are zero on every
   uncorrupted run by construction.  Zero extra syncs.
2. **Host-side protocol checks** (also ``invariants``) — per-level
   discovery popcounts must be positive-then-terminate, cumulative
   discoveries bounded by |V| x planes, final value rows bounded by the
   iteration count with each plane's own root at 0
   (:func:`check_level_rows`, :func:`check_popcount_sequence`).
3. **Sampled witness audit** (mode ``witness``) — for K sampled
   discovered vertices per wave, verify ON DEVICE that some in-neighbor
   sits exactly one level closer (the parent that discovered it).  One
   extra fused reduction riding the run's final fetch; the
   ``host_transfers == iterations + 2`` invariant holds.
4. **Rate-sampled differential audit** (mode ``audit``) — the supervisor
   re-runs a sampled fraction of CLEAN waves through a reference path
   (packed off / pallas off) and compares rows exactly.  Strongest and
   costliest; ``audit_rate`` bounds the amortized overhead.

All violations raise :class:`IntegrityError`, which the supervisor
treats as a KERNEL-CLASS transient fault: retry, then demote down the
``pallas -> jnp -> bool-plane`` ladder (a corrupted kernel rung is the
prime suspect; the bool-plane rung is the audit reference itself).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bfs_local import INF
from repro.core.vertex_program import INTEGRITY_MODES, IntegrityError

__all__ = [
    "INTEGRITY_MODES", "IntegrityConfig", "IntegrityError",
    "check_level_rows", "check_popcount_sequence",
]


@dataclasses.dataclass(frozen=True)
class IntegrityConfig:
    """Supervisor-level integrity policy (engine + host + audit knobs).

    ``mode`` picks the detector tier; ``witness_k``/``witness_budget``
    size the sampled witness reduction; ``audit_rate`` is the fraction of
    clean waves the ``audit`` tier re-runs through the reference path
    (deterministic given ``seed``, so two supervisors audit the same
    schedule only when seeded alike).
    """

    mode: str = "invariants"
    witness_k: int = 64
    witness_budget: int = 4096
    audit_rate: float = 0.05
    seed: int | None = 0

    def __post_init__(self):
        if self.mode not in INTEGRITY_MODES:
            raise ValueError(
                f"integrity mode must be one of {INTEGRITY_MODES}, "
                f"got {self.mode!r}")
        if not (0.0 <= self.audit_rate <= 1.0):
            raise ValueError(
                f"audit_rate must be in [0, 1], got {self.audit_rate}")


def check_level_rows(rows: np.ndarray, roots: np.ndarray,
                     iterations: int | None = None) -> None:
    """Host-side result validation: every value is INF or in
    ``[0, iterations]`` (``[0, n]`` when the iteration count is unknown,
    e.g. after a bool-plane demotion), and each plane's value at its own
    root is exactly 0.  Raises :class:`IntegrityError`.

    This is the check that catches RESULT corruption — e.g. a bit flip in
    the returned rows after the device run completed — which the
    in-flight statvec invariants cannot see.
    """
    rows = np.asarray(rows)
    roots = np.asarray(roots)
    bound = int(iterations) if iterations is not None else rows.shape[1]
    bad = (rows != int(INF)) & ((rows < 0) | (rows > bound))
    if bad.any():
        b, v = (int(x) for x in np.argwhere(bad)[0])
        raise IntegrityError(
            f"{int(bad.sum())} result values outside [0, {bound}] ∪ "
            f"{{INF}} (first: plane {b}, vertex {v}, value "
            f"{int(rows[b, v])})")
    at_root = rows[np.arange(roots.size), roots]
    if np.any(at_root != 0):
        b = int(np.argwhere(at_root != 0)[0][0])
        raise IntegrityError(
            f"plane {b} lost its root: value[{int(roots[b])}] = "
            f"{int(at_root[b])}, expected 0")


def check_popcount_sequence(pcs) -> None:
    """Per-level discovery popcounts must be positive-then-terminate:
    every level before the last discovers at least one (vertex, plane)
    pair, the final level discovers none, and no count is negative.
    A zero mid-sequence means the loop ran on a drained frontier; a
    negative count is a corrupt statvec.  Raises :class:`IntegrityError`.
    """
    pcs = [int(x) for x in pcs]
    if not pcs:
        raise IntegrityError("empty discovery popcount sequence")
    if any(x < 0 for x in pcs):
        raise IntegrityError(f"negative discovery popcount: {pcs}")
    if pcs[0] <= 0:
        raise IntegrityError(
            f"initial discovery popcount {pcs[0]} <= 0 (roots must seed "
            "their own planes)")
    # body counts (between init and the terminating level) must be > 0
    body = pcs[1:-1] if len(pcs) > 1 else []
    if any(x == 0 for x in body):
        lvl = 1 + body.index(0)
        raise IntegrityError(
            f"discovery popcount hit 0 at level {lvl} but the traversal "
            f"ran {len(pcs) - 1} levels (positive-then-terminate "
            "violated)")
    if len(pcs) > 1 and pcs[-1] != 0:
        raise IntegrityError(
            f"traversal ended with nonzero discovery popcount "
            f"{pcs[-1]} (frontier not drained)")
