"""Fault-tolerant serving: the engine supervisor.

ScalaBFS earns its GTEPS by keeping all 32 HBM pseudo-channels busy every
cycle; the serving-stack analogue of one stalled channel is a hung or
poisoned wave taking the whole ``DynamicBatcher`` down with it.  This
module wraps any ``BFSEngine`` (the protocol in ``repro.core.bfs_local``)
in an :class:`EngineSupervisor` that makes per-wave behavior bounded and
typed — the property the memory-access-pattern literature (Dann & Ritter
2021, GraphScale 2022) identifies as what graph accelerators live or die
by under skewed inputs:

* **Wave watchdog** — each engine call gets a deadline derived from the
  recent :class:`~repro.ft.failures.StepTimer` history (``k × running
  median``, clamped) or set explicitly; a wave that exceeds it is
  abandoned and surfaces as a typed :class:`WaveTimeout` instead of
  stalling the batcher forever.
* **Typed retry with backoff** — transient faults (injected, kernel,
  runtime) retry the whole wave up to ``max_retries`` with exponential
  backoff; exhausted retries fail the wave's requests with
  :class:`WaveAbandoned`.
* **Quarantine bisection** — a wave that fails *deterministically* (bad
  input classes: ``ValueError``/``TypeError``/…) is split in half and each
  half retried recursively, isolating the poisoned request(s) in O(log B)
  extra traversals so the other B−1 co-batched users still get answers.
  The isolated root's future fails with :class:`RequestQuarantined`
  chaining the root cause.
* **Graceful degradation ladder** — repeated kernel faults step the engine
  down ``pallas=True → jnp fallback → packed=False`` (per-wave by default,
  ``sticky_demotions=True`` to keep), recording each demotion; persistent
  push-budget overflow (``core.vertex_program.BudgetOverflowError``)
  escalates the edge budget for the retry wave via the engine's per-wave
  ``budget=`` override.
* **Deterministic chaos harness** — :class:`FaultPlan` schedules
  (wave-index, fault-kind) injections exactly once at the engine boundary
  and :class:`FaultyEngine` is the matching test double, so chaos tests
  and the ``benchmarks/msbfs_serving.py --chaos`` arm are fully
  reproducible.

The supervisor itself satisfies the ``BFSEngine`` protocol
(``num_vertices`` / ``out_deg`` / ``run_batch`` / ``last_stats``) so it
drops in front of ``DynamicBatcher`` transparently; the batcher detects it
and delegates per-request resolution to :meth:`EngineSupervisor.run_wave`.
"""
from __future__ import annotations

import dataclasses
import inspect
import threading
import time

import numpy as np

from repro.core import bitmap
from repro.core.bfs_local import engine_num_vertices
from repro.core.vertex_program import BudgetOverflowError, IntegrityError
from repro.ft.failures import InjectedFailure, StepTimer
from repro.ft.integrity import (IntegrityConfig, check_level_rows,
                                check_popcount_sequence)

# ---------------------------------------------------------------------------
# Typed error taxonomy
# ---------------------------------------------------------------------------


class ServingError(RuntimeError):
    """Base of the serving fault taxonomy (every supervisor-raised error)."""


class KernelFault(ServingError):
    """A device-kernel (Pallas/XLA) failure — transient at wave scope, but
    repeated occurrences drive the degradation ladder."""


class WaveTimeout(ServingError):
    """The wave exceeded its watchdog deadline and was abandoned."""


class WaveAbandoned(ServingError):
    """Transient faults persisted past ``max_retries``; the wave's
    requests fail with this error chaining the last fault."""


class RequestQuarantined(ServingError):
    """Bisection isolated this root as the deterministic poison in its
    wave; the root cause is chained as ``__cause__``."""


class PoisonedRoot(ValueError):
    """A request that deterministically fails its wave (test double's
    poison marker; ``ValueError`` so it classifies as deterministic just
    like a malformed-input rejection)."""


TRANSIENT, DETERMINISTIC = "transient", "deterministic"

# Input-shaped errors: retrying the identical wave cannot help, so the
# supervisor bisects to isolate the poisoned request instead.
_DETERMINISTIC_TYPES = (ValueError, TypeError, IndexError, KeyError,
                        NotImplementedError)


def classify_fault(exc: BaseException) -> str:
    """Map an engine failure to the retry policy it gets.

    Deterministic (bad input — bisect, don't retry): ``ValueError`` and
    friends, the classes a malformed root / shape mismatch raises.
    Transient (retry with backoff): everything else — injected faults,
    kernel faults, runtime/device errors, watchdog timeouts.
    """
    if isinstance(exc, _DETERMINISTIC_TYPES):
        return DETERMINISTIC
    return TRANSIENT


def is_kernel_fault(exc: BaseException) -> bool:
    """Kernel-shaped failures drive the degradation ladder.

    Typed :class:`KernelFault` always qualifies; otherwise best-effort
    string matching on the exception's type/module/message for the Pallas
    and XLA compiler/runtime fingerprints.
    """
    if isinstance(exc, KernelFault):
        return True
    if isinstance(exc, IntegrityError):
        # a violated traversal invariant means the engine computed WRONG
        # words — a corrupted kernel rung is the prime suspect, so the
        # retry should walk the same pallas -> jnp -> bool-plane ladder
        return True
    if isinstance(exc, _DETERMINISTIC_TYPES):
        return False
    blob = (f"{type(exc).__module__}.{type(exc).__name__} "
            f"{exc}").lower()
    return any(tag in blob for tag in ("pallas", "xla", "mosaic", "triton"))


def supports_budget_override(engine) -> bool:
    """True if ``engine.run_batch`` accepts the per-wave ``budget=`` kw
    (``VertexProgramRunner`` does; ``DistributedBFS`` does not)."""
    try:
        params = inspect.signature(engine.run_batch).parameters
    except (TypeError, ValueError):
        return False
    if "budget" in params:
        return True
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values())


def find_tunable_engine(engine):
    """Walk a wrapper chain (``.inner`` / ``._inner`` / ``.engine``) to the
    object that owns the ``use_pallas`` / ``packed`` knobs the degradation
    ladder turns.  Returns None when nothing in the chain is tunable."""
    seen: set[int] = set()
    obj = engine
    while obj is not None and id(obj) not in seen:
        seen.add(id(obj))
        d = getattr(obj, "__dict__", {})
        if "use_pallas" in d or "packed" in d:
            return obj
        obj = (getattr(obj, "inner", None) or getattr(obj, "_inner", None)
               or getattr(obj, "engine", None))
    return None


# ---------------------------------------------------------------------------
# Per-wave outcome records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RootOutcome:
    """How one submitted root ended: a level row or a typed error."""

    root: int
    levels: np.ndarray | None = None
    error: BaseException | None = None

    @property
    def ok(self) -> bool:
        return self.error is None and self.levels is not None


@dataclasses.dataclass
class SupervisedWave:
    """One logical wave's fate under the supervisor's policy."""

    roots: np.ndarray
    outcomes: list[RootOutcome]
    traversals: int = 0        # engine calls issued (retries + bisection)
    fault_waves: int = 0       # engine calls that raised
    retries: int = 0           # transient-fault retries
    timeouts: int = 0          # watchdog abandonments
    bisections: int = 0        # splits performed isolating poison
    budget_escalations: int = 0
    quarantined: list[int] = dataclasses.field(default_factory=list)
    demotions: list[str] = dataclasses.field(default_factory=list)
    seconds: float = 0.0       # engine-busy wall time incl. failed attempts
    stats: dict = dataclasses.field(default_factory=dict)
    _kernel_faults: int = dataclasses.field(default=0, repr=False)

    @property
    def n_ok(self) -> int:
        return sum(o.ok for o in self.outcomes)

    @property
    def n_failed(self) -> int:
        return len(self.outcomes) - self.n_ok

    def levels(self) -> np.ndarray:
        """Stacked [B, n] rows; raises the first typed error if any root
        failed (the strict engine-protocol view of a partial wave)."""
        for o in self.outcomes:
            if o.error is not None:
                raise o.error
        return np.stack([o.levels for o in self.outcomes])


# ---------------------------------------------------------------------------
# The supervisor
# ---------------------------------------------------------------------------


class EngineSupervisor:
    """Wrap a ``BFSEngine`` with watchdog + retry + bisection + degradation.

    One wave at a time (the dynamic batcher's worker already serializes
    waves); not safe for concurrent ``run_wave`` calls on one instance.

    Parameters
    ----------
    max_retries: transient-fault retries per (sub-)wave before abandoning.
    backoff / backoff_factor: exponential retry backoff seconds.
    wave_deadline: explicit watchdog deadline (seconds); None derives
        ``timer.k × running-median`` clamped to [min_deadline,
        max_deadline] once ≥ 3 wave durations are recorded (a cold engine
        is never deadlined — the first waves pay jit compilation).
    watchdog: False disables deadlines entirely (engine runs inline, no
        guard thread).
    degrade: enable the kernel-fault demotion ladder
        (``use_pallas → jnp → packed=False``).
    sticky_demotions: keep demotions across waves instead of restoring the
        engine's knobs at wave end.
    demotion_slack: multiply the watchdog deadline by this per demotion
        taken — the ladder's lower rungs (jnp fallback, bool-plane) are
        known to be slower, and without slack a demoted wave would trip
        the same watchdog that the demotion was meant to satisfy.
    escalate_budget: retry ``BudgetOverflowError`` waves with a doubled
        edge budget, and start later waves at the deepest budget a
        previous wave settled on (both via ``run_batch(budget=)``).
    pad_to_plane: pad every engine call to whole uint32 plane words so
        bisection sub-waves reuse the jitted wave shapes.
    integrity: an :class:`~repro.ft.integrity.IntegrityConfig` (or a mode
        string) enabling per-wave answer validation: engine-side statvec
        invariants + witness reduction (pushed onto the tunable runner's
        knobs), host-side row/popcount checks on every served wave, and —
        mode ``audit`` — a rate-sampled full differential re-run against
        the reference path.  Violations raise
        :class:`~repro.core.IntegrityError` inside the attempt, riding
        the normal retry/demotion policy.  None = off.
    jitter: decorrelate retry backoff (``delay = uniform(backoff,
        3 x delay)``, capped) so pool workers sharing a fault do not
        retry in lockstep; ``jitter_seed=None`` (default) seeds from OS
        entropy, so two supervisors' schedules diverge.
    timer / clock / sleep: injectable for deterministic tests.
    """

    def __init__(self, engine, *, max_retries: int = 2,
                 backoff: float = 0.02, backoff_factor: float = 2.0,
                 backoff_cap: float = 2.0,
                 wave_deadline: float | None = None,
                 min_deadline: float = 0.25, max_deadline: float = 60.0,
                 watchdog: bool = True, degrade: bool = True,
                 sticky_demotions: bool = False,
                 demotion_slack: float = 4.0,
                 escalate_budget: bool = True, pad_to_plane: bool = True,
                 integrity: IntegrityConfig | str | None = None,
                 jitter: bool = True, jitter_seed: int | None = None,
                 timer: StepTimer | None = None, clock=None, sleep=None):
        if max_retries < 0 or backoff < 0 or backoff_factor < 1:
            raise ValueError("need max_retries >= 0, backoff >= 0, "
                             "backoff_factor >= 1")
        self.engine = engine
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.backoff_factor = float(backoff_factor)
        self.backoff_cap = float(backoff_cap)
        self.jitter = bool(jitter)
        self._retry_rng = np.random.default_rng(jitter_seed)
        # delays actually waited, in order (the jitter-divergence test's
        # observable: two default-seeded supervisors must NOT share it)
        self.backoff_log: list[float] = []
        self.wave_deadline = wave_deadline
        self.min_deadline = float(min_deadline)
        self.max_deadline = float(max_deadline)
        self.watchdog = bool(watchdog)
        self.degrade = bool(degrade)
        self.sticky_demotions = bool(sticky_demotions)
        self.demotion_slack = float(demotion_slack)
        self._deadline_scale = 1.0
        self.escalate_budget = bool(escalate_budget)
        self.pad_to_plane = bool(pad_to_plane)
        self.timer = timer if timer is not None else StepTimer(k=4.0)
        self.clock = time.monotonic if clock is None else clock
        self.sleep = time.sleep if sleep is None else sleep
        self._supports_budget = supports_budget_override(engine)
        self._tunable = find_tunable_engine(engine)
        if isinstance(integrity, str):
            integrity = IntegrityConfig(mode=integrity)
        self.integrity = integrity
        self._audit_rng = np.random.default_rng(
            None if integrity is None else integrity.seed)
        self._n_integrity_checks = self._n_integrity_violations = 0
        self._n_audits = self._n_audit_failures = 0
        if integrity is not None and integrity.mode != "off":
            self._push_integrity_knobs(integrity)
        self._budget_hint: int | None = None
        self._zombie: threading.Thread | None = None
        self._wave_deadline_override: float | None = None
        self.last_stats: dict = {}
        # lifetime counters (stats() snapshot)
        self._n_waves = self._n_traversals = self._n_fault_waves = 0
        self._n_retries = self._n_timeouts = self._n_bisections = 0
        self._n_budget_escalations = self._n_stragglers = 0
        self._quarantined: list[int] = []
        self._demotions: list[str] = []

    # -- BFSEngine protocol ----------------------------------------------

    @property
    def num_vertices(self) -> int | None:
        return engine_num_vertices(self.engine)

    @property
    def out_deg(self):
        return getattr(self.engine, "out_deg", None)

    def run_batch(self, roots) -> np.ndarray:
        """Strict protocol entry: all-or-error view of a supervised wave.

        Prefer :meth:`run_wave` for per-request outcomes (what
        ``DynamicBatcher`` uses); this raises the first root's typed error
        when any request failed.
        """
        return self.run_wave(roots).levels()

    # -- watchdog deadline ------------------------------------------------

    def current_deadline(self) -> float | None:
        """The deadline the NEXT engine call would get (None = no guard).

        Scaled by ``demotion_slack`` per demotion taken this wave: a
        demoted engine is expected slower, and an unscaled deadline would
        time out the very fallback the ladder just switched to.
        """
        if not self.watchdog:
            return None
        if self._wave_deadline_override is not None:
            # per-wave SLO from the serving layer (run_wave(deadline=)):
            # floored at min_deadline so a nearly-expired SLO still gets
            # one real attempt instead of an instant timeout, and capped
            # by the configured wave_deadline when both are set
            d = max(float(self._wave_deadline_override), self.min_deadline)
            if self.wave_deadline is not None:
                d = min(d, float(self.wave_deadline))
            return d * self._deadline_scale
        if self.wave_deadline is not None:
            return float(self.wave_deadline) * self._deadline_scale
        med = self.timer.median()
        if med is None or len(self.timer.durations) < 3:
            return None               # cold engine: compilation is not a hang
        return min(max(self.timer.k * med, self.min_deadline),
                   self.max_deadline) * self._deadline_scale

    # -- the supervised wave ---------------------------------------------

    def run_wave(self, roots,
                 deadline: float | None = None) -> SupervisedWave:
        """Serve a wave of roots under the full fault policy.

        EVERY root resolves: ``outcomes[i]`` carries either its level row
        or a typed error (``WaveTimeout`` / ``WaveAbandoned`` /
        ``RequestQuarantined`` / the original deterministic error for a
        singleton wave).  Never raises for engine failures.

        ``deadline`` (seconds, relative) overrides the watchdog deadline
        for THIS wave only — the serving layer passes the tightest
        remaining request SLO here, so the watchdog enforces it during
        execution (including retries and bisection sub-waves) rather than
        letting a doomed wave run to the statistical deadline.  Requires
        the watchdog to be enabled; floored at ``min_deadline``.
        """
        roots = np.asarray(roots)
        wave = SupervisedWave(
            roots=roots,
            outcomes=[RootOutcome(int(r)) for r in roots])
        snapshot = self._snapshot_knobs()
        self._wave_deadline_override = deadline
        try:
            self._serve(wave, roots, wave.outcomes)
        finally:
            self._wave_deadline_override = None
            if not self.sticky_demotions:
                self._restore_knobs(snapshot)
                self._deadline_scale = 1.0
        self._n_waves += 1
        self._n_traversals += wave.traversals
        self._n_fault_waves += wave.fault_waves
        self._n_retries += wave.retries
        self._n_timeouts += wave.timeouts
        self._n_bisections += wave.bisections
        self._n_budget_escalations += wave.budget_escalations
        self._quarantined.extend(wave.quarantined)
        self._demotions.extend(wave.demotions)
        self.last_stats = dict(wave.stats, ft_traversals=wave.traversals,
                               ft_retries=wave.retries,
                               ft_quarantined=len(wave.quarantined))
        return wave

    def _serve(self, wave: SupervisedWave, roots: np.ndarray,
               outcomes: list[RootOutcome]):
        """Retry-then-bisect policy for one (sub-)wave, resolving every
        outcome in place."""
        tries = 0
        delay = self.backoff
        budget = self._budget_hint
        while True:
            wave.traversals += 1
            try:
                rows, stats, dt = self._attempt(roots, budget)
            except Exception as exc:      # noqa: BLE001 — policy boundary
                wave.fault_waves += 1
                wave.seconds += self._last_attempt_seconds
                if isinstance(exc, IntegrityError):
                    # count every violation ONCE at the policy boundary —
                    # engine-raised (device statvec / witness) and
                    # host-raised (row bounds / popcounts / audit) alike
                    self._n_integrity_violations += 1
                if classify_fault(exc) == DETERMINISTIC:
                    if len(outcomes) == 1:
                        root = outcomes[0].root
                        wave.quarantined.append(root)
                        err = RequestQuarantined(
                            f"root {root} isolated by bisection: "
                            f"{type(exc).__name__}: {exc}")
                        err.__cause__ = exc
                        outcomes[0].error = err
                        return
                    # bisect: isolate the poison in O(log B) sub-waves so
                    # the clean co-batched requests still get answers
                    mid = len(outcomes) // 2
                    wave.bisections += 1
                    self._serve(wave, roots[:mid], outcomes[:mid])
                    self._serve(wave, roots[mid:], outcomes[mid:])
                    return
                # transient fault: retry with backoff, possibly demoted
                if isinstance(exc, WaveTimeout):
                    wave.timeouts += 1
                if is_kernel_fault(exc):
                    wave._kernel_faults += 1
                    if self.degrade and wave._kernel_faults >= 2:
                        demoted = self._demote()
                        if demoted:
                            wave.demotions.append(demoted)
                if (isinstance(exc, BudgetOverflowError)
                        and self.escalate_budget):
                    budget = 2 * max(budget or 0, exc.budget)
                    wave.budget_escalations += 1
                tries += 1
                if tries > self.max_retries:
                    for o in outcomes:
                        if o.error is None and o.levels is None:
                            err = WaveAbandoned(
                                f"wave of {len(outcomes)} roots abandoned "
                                f"after {tries} attempts: "
                                f"{type(exc).__name__}: {exc}")
                            err.__cause__ = exc
                            o.error = err
                    return
                wave.retries += 1
                self.backoff_log.append(delay)
                self._backoff_wait(delay)
                delay = self._next_delay(delay)
            else:
                wave.seconds += dt
                wave.stats = stats
                if (self.escalate_budget
                        and stats.get("overflow_retries", 0) > 0
                        and stats.get("budget", 0) > 0):
                    # the wave deepened mid-flight: start later waves at
                    # the budget it settled on instead of re-deepening
                    self._budget_hint = int(stats["budget"])
                for o, row in zip(outcomes, rows):
                    o.levels = np.ascontiguousarray(row)
                return

    # -- one guarded engine call ------------------------------------------

    def _call_engine(self, slots, budget):
        if budget is not None and self._supports_budget:
            return self.engine.run_batch(slots, budget=int(budget))
        return self.engine.run_batch(slots)

    def _attempt(self, roots: np.ndarray, budget: int | None):
        """One engine traversal with the watchdog armed; pads to plane
        words so bisection sub-waves hit already-jitted shapes."""
        slots, b = (bitmap.pad_plane_slots(roots) if self.pad_to_plane
                    else (roots, len(roots)))
        deadline = self.current_deadline()
        self._last_attempt_seconds = 0.0
        t0 = time.perf_counter()
        try:
            if deadline is None:
                levels = self._call_engine(slots, budget)
            else:
                box: dict = {}
                done = threading.Event()

                def work():
                    try:
                        box["levels"] = self._call_engine(slots, budget)
                    except BaseException as e:  # noqa: BLE001
                        box["exc"] = e
                    finally:
                        done.set()

                th = threading.Thread(target=work, daemon=True,
                                      name="supervised-wave")
                th.start()
                if not done.wait(deadline):
                    # abandon: the guard thread may still finish later;
                    # its result is discarded and the next backoff joins it
                    self._zombie = th
                    raise WaveTimeout(
                        f"wave of {len(roots)} roots exceeded the "
                        f"{deadline:.3f}s watchdog deadline")
                if "exc" in box:
                    raise box["exc"]
                levels = box["levels"]
        finally:
            self._last_attempt_seconds = time.perf_counter() - t0
        dt = self._last_attempt_seconds
        if self.timer.record(len(self.timer.durations), dt):
            self._n_stragglers += 1
        stats = dict(getattr(self.engine, "last_stats", {}) or {})
        rows = np.asarray(levels)
        if self.pad_to_plane:
            rows = bitmap.slice_plane_rows(rows, b)
        # integrity validation happens AFTER timer.record: a failed check
        # re-enters _serve as a kernel-class fault, and audit re-runs must
        # not inflate the watchdog's wave-duration history
        if self.integrity is not None and self.integrity.mode != "off":
            self._validate_wave(rows, np.asarray(roots), slots, stats,
                                budget)
        return rows, stats, dt

    def _validate_wave(self, rows: np.ndarray, roots: np.ndarray,
                       slots: np.ndarray, stats: dict,
                       budget: int | None) -> None:
        """Host-side answer validation for one successful attempt; raises
        :class:`IntegrityError` (kernel-class, so _serve retries/demotes).

        Row bounds + root-zero run on every wave (this is the check that
        catches RESULT corruption the in-flight statvec slots cannot see);
        popcount positive-then-terminate runs when the engine recorded the
        sequence; mode ``audit`` additionally re-runs a sampled fraction
        of waves through the reference rung (packed off, else pallas off)
        and compares rows exactly.
        """
        self._n_integrity_checks += 1
        check_level_rows(rows, roots, stats.get("iterations"))
        pcs = stats.get("discovery_popcounts")
        if pcs is not None:
            check_popcount_sequence(pcs)
        if (self.integrity.mode == "audit"
                and self._audit_rng.random() < self.integrity.audit_rate):
            self._differential_audit(rows, slots, budget)

    def _differential_audit(self, rows: np.ndarray, slots: np.ndarray,
                            budget: int | None) -> None:
        """Re-run the padded wave through the reference rung and compare.

        Talks to the TUNABLE runner directly (not ``self.engine``): a
        chaos wrapper in between would advance its fault schedule and
        could inject into the reference itself.  Knobs are restored even
        when the audit raises.
        """
        t = self._tunable
        if t is None:
            return
        d = getattr(t, "__dict__", {})
        knob = ("packed" if d.get("packed", False)
                else "use_pallas" if d.get("use_pallas", False) else None)
        if knob is None:
            return            # already ON the reference rung: nothing to diff
        self._n_audits += 1
        saved = getattr(t, knob)
        try:
            setattr(t, knob, False)
            ref = np.asarray(self._call_tunable(t, slots, budget))
            ref = bitmap.slice_plane_rows(ref, rows.shape[0])
        finally:
            setattr(t, knob, saved)
        if not np.array_equal(ref, rows):
            self._n_audit_failures += 1
            bad = int(np.sum(np.any(ref != rows, axis=1)))
            raise IntegrityError(
                f"differential audit mismatch: {bad}/{rows.shape[0]} "
                f"planes differ from the {knob}=False reference")

    @staticmethod
    def _call_tunable(t, slots, budget):
        if budget is not None and supports_budget_override(t):
            return t.run_batch(slots, budget=int(budget))
        return t.run_batch(slots)

    def _push_integrity_knobs(self, cfg: IntegrityConfig) -> None:
        """Configure ENGINE-side checking on the tunable runner: statvec
        invariant slot + (witness/audit) the sampled witness reduction.
        No-op for engines without the knobs (e.g. DistributedBFS) — the
        host-side checks in :meth:`_validate_wave` still apply."""
        t = self._tunable
        if t is None or "integrity" not in getattr(t, "__dict__", {}):
            return
        t.integrity = cfg.mode
        t.witness_k = cfg.witness_k
        t.witness_budget = cfg.witness_budget

    def _next_delay(self, delay: float) -> float:
        """Next retry delay: plain exponential when ``jitter=False``,
        decorrelated jitter (``uniform(backoff, 3 x delay)``, capped at
        ``backoff_cap``) otherwise — correlated faults across pool
        workers then spread their retries instead of re-colliding."""
        if not self.jitter:
            return delay * self.backoff_factor
        hi = max(3.0 * delay, self.backoff)
        return min(self.backoff_cap,
                   float(self._retry_rng.uniform(self.backoff, hi)))

    def _backoff_wait(self, delay: float):
        """Back off before a retry; if a timed-out wave's guard thread is
        still running, spend the backoff joining it (keeps the engine from
        seeing two concurrent waves in the common case)."""
        z = self._zombie
        if z is not None and z.is_alive():
            z.join(delay if delay > 0 else None)
        elif delay > 0:
            self.sleep(delay)
        if z is not None and not z.is_alive():
            self._zombie = None

    # -- degradation ladder ----------------------------------------------

    def _snapshot_knobs(self) -> dict:
        t = self._tunable
        if t is None:
            return {}
        return {k: getattr(t, k) for k in ("use_pallas", "packed")
                if k in getattr(t, "__dict__", {})}

    def _restore_knobs(self, snapshot: dict):
        for k, v in snapshot.items():
            setattr(self._tunable, k, v)

    def _demote(self) -> str | None:
        """Step the engine one rung down the ladder; returns the demotion
        label, or None when the bottom is reached / nothing is tunable."""
        t = self._tunable
        if t is None:
            return None
        if getattr(t, "use_pallas", False):
            t.use_pallas = False
            self._deadline_scale *= self.demotion_slack
            return "pallas->jnp"
        if getattr(t, "packed", False):
            t.packed = False
            self._deadline_scale *= self.demotion_slack
            return "packed->boolplane"
        return None

    # -- reporting ---------------------------------------------------------

    def stats(self) -> dict:
        """Lifetime supervisor counters (JSON-friendly)."""
        out = dict(
            waves=self._n_waves, traversals=self._n_traversals,
            fault_waves=self._n_fault_waves, retries=self._n_retries,
            timeouts=self._n_timeouts, bisections=self._n_bisections,
            budget_escalations=self._n_budget_escalations,
            stragglers=self._n_stragglers,
            quarantined=list(self._quarantined),
            demotions=list(self._demotions),
        )
        dl = self.current_deadline()
        if dl is not None:
            out["wave_deadline"] = round(float(dl), 4)
        if self._budget_hint is not None:
            out["budget_hint"] = int(self._budget_hint)
        if self.integrity is not None:
            out["integrity"] = dict(
                mode=self.integrity.mode,
                checks=self._n_integrity_checks,
                violations=self._n_integrity_violations,
                audits=self._n_audits,
                audit_failures=self._n_audit_failures)
        return out


# ---------------------------------------------------------------------------
# Deterministic chaos harness
# ---------------------------------------------------------------------------

FAULT_KINDS = ("kernel", "runtime", "stuck", "plane_flip", "result_flip")


class FaultPlan:
    """Exact-once (engine-call index -> fault kind) schedule.

    The index counts ENGINE CALLS at the supervised boundary — retries and
    bisection sub-waves advance it too, so a schedule pins faults to a
    reproducible point of the serving run regardless of wall clock.
    """

    def __init__(self, faults=()):
        self._faults: dict[int, str] = {}
        for idx, kind in faults:
            if kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r}; have {FAULT_KINDS}")
            if int(idx) in self._faults:
                raise ValueError(f"duplicate fault at wave index {idx}")
            self._faults[int(idx)] = kind
        self.injected: list[tuple[int, str]] = []

    @classmethod
    def random(cls, horizon: int, rate: float, *,
               kinds=("kernel", "runtime"), seed: int = 0) -> "FaultPlan":
        """Bernoulli(rate) fault per wave index over ``horizon`` calls,
        cycling through ``kinds`` — deterministic given ``seed``."""
        rng = np.random.default_rng(seed)
        hits = np.flatnonzero(rng.random(int(horizon)) < rate)
        return cls([(int(i), kinds[k % len(kinds)])
                    for k, i in enumerate(hits)])

    def pop(self, idx: int) -> str | None:
        kind = self._faults.pop(int(idx), None)
        if kind is not None:
            self.injected.append((int(idx), kind))
        return kind

    def pending(self) -> dict[int, str]:
        return dict(self._faults)

    def __len__(self) -> int:
        return len(self._faults)


class FaultyEngine:
    """BFSEngine-protocol chaos test double wrapping a real engine.

    Injects, at the engine boundary the supervisor guards:

    * plan-scheduled faults — ``kernel`` raises :class:`KernelFault`,
      ``runtime`` raises :class:`InjectedFailure`, ``stuck`` stalls
      ``stall_seconds`` before serving (tripping the watchdog when the
      deadline is shorter);
    * poisoned roots — any wave containing one raises
      :class:`PoisonedRoot` (deterministic, every time), which the
      supervisor isolates by bisection;
    * ``break_pallas=True`` — raises :class:`KernelFault` whenever the
      underlying engine still has ``use_pallas`` enabled, emulating a
      broken kernel toolchain until the ladder demotes to the jnp
      fallback;
    * bit-flip corruption (SILENT faults — nothing raises; only the
      integrity layer can catch them): ``plane_flip`` arms the runner's
      exact-once ``_corrupt_plane`` hook, XOR-ing one frontier plane bit
      mid-traversal at (level, vertex, plane) — ``plane_flip=`` pins the
      target, otherwise it derives deterministically from the call index;
      ``result_flip`` XORs one bit of the RETURNED level rows at
      (row, vertex, bit) after the inner engine finished (``result_flip=``
      pins it; bit defaults to 16 so any level or INF lands outside the
      valid range and the row-bounds check must fire).  Every flip is
      recorded in ``self.flips``.

    The inner engine is called under a lock so a timed-out (zombie) wave
    finishing late never overlaps a retry's traversal.
    """

    def __init__(self, inner, plan: FaultPlan | None = None, *,
                 poisoned_roots=(), stall_seconds: float = 0.25,
                 break_pallas: bool = False,
                 plane_flip: tuple[int, int, int] | None = None,
                 result_flip: tuple[int, int, int] | None = None,
                 sleep=None):
        self.inner = inner
        self.plan = plan if plan is not None else FaultPlan()
        self.poisoned = {int(r) for r in poisoned_roots}
        self.stall_seconds = float(stall_seconds)
        self.break_pallas = bool(break_pallas)
        self.plane_flip = plane_flip
        self.result_flip = result_flip
        self.flips: list[dict] = []
        self.sleep = time.sleep if sleep is None else sleep
        self.calls = 0
        self._lock = threading.Lock()
        self._supports_budget = supports_budget_override(inner)

    # protocol passthrough
    @property
    def num_vertices(self):
        return engine_num_vertices(self.inner)

    @property
    def out_deg(self):
        return getattr(self.inner, "out_deg", None)

    @property
    def last_stats(self):
        return getattr(self.inner, "last_stats", {})

    def run_batch(self, roots, *, budget: int | None = None) -> np.ndarray:
        idx = self.calls
        self.calls += 1
        hit = self.poisoned.intersection(int(r) for r in np.asarray(roots))
        if hit:
            raise PoisonedRoot(
                f"poisoned root(s) {sorted(hit)} in wave {idx}")
        tunable = find_tunable_engine(self.inner)
        if self.break_pallas and getattr(tunable, "use_pallas", False):
            raise KernelFault(
                f"pallas lowering failed at wave {idx} (break_pallas)")
        kind = self.plan.pop(idx)
        if kind == "kernel":
            raise KernelFault(f"injected kernel fault at wave {idx}")
        if kind == "runtime":
            raise InjectedFailure(f"injected runtime fault at wave {idx}")
        if kind == "stuck":
            self.sleep(self.stall_seconds)
        if kind == "plane_flip":
            spec = self.plane_flip or (
                1 + idx % 2,
                (1103515245 * idx + 7) % max(1, self.num_vertices or 1),
                idx % max(1, len(np.asarray(roots))))
            if tunable is not None and hasattr(tunable, "_corrupt_plane"):
                tunable._corrupt_plane = tuple(int(x) for x in spec)
                self.flips.append(dict(wave=idx, kind=kind,
                                       target=list(spec)))
        with self._lock:
            if budget is not None and self._supports_budget:
                rows = self.inner.run_batch(roots, budget=budget)
            else:
                rows = self.inner.run_batch(roots)
        if tunable is not None and getattr(tunable, "_corrupt_plane",
                                           None) is not None:
            # the target level was never reached (or the engine is not a
            # packed runner): disarm so the flip cannot leak into a later,
            # unscheduled wave
            tunable._corrupt_plane = None
        if kind == "result_flip":
            rows = np.array(rows)            # corrupt a COPY, post-engine
            r, v, bit = self.result_flip or (
                idx % rows.shape[0],
                (1103515245 * idx + 13) % rows.shape[1], 16)
            rows[int(r) % rows.shape[0],
                 int(v) % rows.shape[1]] ^= np.int32(1 << int(bit))
            self.flips.append(dict(wave=idx, kind=kind,
                                   target=[int(r), int(v), int(bit)]))
        return rows
