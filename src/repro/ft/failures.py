"""Fault tolerance: failure injection, retry-from-checkpoint, stragglers.

At 1000+ nodes, the dominant failure modes are (a) preempted/crashed hosts,
(b) slow hosts (stragglers), (c) data corruption.  The policies here are the
single-controller analogues, exercised by tests with injected faults:

* ``run_with_retries`` — wraps a step function; on failure restores the
  latest checkpoint and replays (the data pipeline is a pure function of
  (seed, step), so replay is exact).
* ``FailureInjector`` — deterministic fault schedule for tests/examples.
* Stragglers: level-synchronous BFS and synchronous data-parallel training
  both barrier per step, so mitigation = balanced partitioning (the paper's
  hash interval scheme) + bounded per-step work (edge budgets / fixed batch
  shapes).  ``StepTimer`` flags outlier steps so a deployment can evict
  slow hosts (documented policy; eviction needs a cluster manager).
"""
from __future__ import annotations

import time


class InjectedFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises InjectedFailure at the scheduled step numbers (once each)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise InjectedFailure(f"injected failure at step {step}")


class StepTimer:
    """Tracks step durations; flags stragglers above k× the running median."""

    def __init__(self, k: float = 3.0, window: int = 50):
        self.k = k
        self.window = window
        self.durations: list[float] = []
        self.flags: list[int] = []

    def record(self, step: int, seconds: float):
        self.durations.append(seconds)
        hist = sorted(self.durations[-self.window:])
        med = hist[len(hist) // 2]
        if len(hist) >= 5 and seconds > self.k * med:
            self.flags.append(step)
            return True
        return False


def run_with_retries(step_fn, restore_fn, num_steps: int, start_step: int = 0,
                     max_retries: int = 3, injector: FailureInjector | None = None,
                     timer: StepTimer | None = None):
    """Drive ``step_fn(step) -> state`` with restore-and-replay on failure.

    restore_fn() -> step to resume from (reloads state inside).
    Returns (completed_steps, num_restarts).
    """
    step = start_step
    restarts = 0
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            if injector is not None:
                injector.check(step)
            step_fn(step)
            if timer is not None:
                timer.record(step, time.perf_counter() - t0)
            step += 1
        except (InjectedFailure, RuntimeError):
            restarts += 1
            if restarts > max_retries:
                raise
            step = restore_fn()
    return step, restarts
