"""Fault tolerance primitives: failure injection, retry-from-checkpoint,
straggler timing.

At 1000+ nodes, the dominant failure modes are (a) preempted/crashed hosts,
(b) slow hosts (stragglers), (c) data corruption.  These primitives are the
single-controller analogues, and they are LIVE policy, not documentation:
``repro.ft.supervisor.EngineSupervisor`` wires them around the serving
engine (``launch.dynbatch`` delegates its whole failure policy to it), and
the chaos harness (``FaultPlan`` / ``FaultyEngine`` in the same module)
drives them deterministically in tests and CI.

* ``run_with_retries`` — wraps a step function; on failure restores the
  latest checkpoint and replays (the data pipeline is a pure function of
  (seed, step), so replay is exact).  Exercised end-to-end against
  ``repro.ckpt.checkpoint`` in ``tests/test_ft.py``.
* ``FailureInjector`` — deterministic exact-once fault schedule keyed by
  step number (the training-loop counterpart of ``FaultPlan``'s
  wave-indexed schedule).
* ``StepTimer`` — records step durations and flags stragglers above k× the
  running median.  The serving supervisor feeds every engine-wave duration
  through one of these, and derives its wave-watchdog deadline from the
  same running median (``StepTimer.median``), so the deadline tracks the
  measured service time instead of a hand-tuned constant.
"""
from __future__ import annotations

import time


class InjectedFailure(RuntimeError):
    """A fault raised by the deterministic injection machinery (transient
    by definition: the schedule is exact-once, so a retry succeeds)."""


class FailureInjector:
    """Raises InjectedFailure at the scheduled step numbers (once each)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise InjectedFailure(f"injected failure at step {step}")


class StepTimer:
    """Tracks step durations; flags stragglers above k× the running median.

    Besides flagging, the running median is the calibration input for the
    serving wave watchdog: ``EngineSupervisor`` deadlines a wave at
    ``k * median`` of the recent history (clamped), so one stuck wave is
    abandoned instead of stalling the whole batcher.
    """

    def __init__(self, k: float = 3.0, window: int = 50):
        self.k = k
        self.window = window
        self.durations: list[float] = []
        self.flags: list[int] = []

    def median(self) -> float | None:
        """Running median over the retained window (None before any
        record) — the watchdog-deadline calibration input."""
        if not self.durations:
            return None
        hist = sorted(self.durations[-self.window:])
        return hist[len(hist) // 2]

    def record(self, step: int, seconds: float) -> bool:
        self.durations.append(seconds)
        med = self.median()
        if len(self.durations[-self.window:]) >= 5 and seconds > self.k * med:
            self.flags.append(step)
            return True
        return False


def run_with_retries(step_fn, restore_fn, num_steps: int, start_step: int = 0,
                     max_retries: int = 3, injector: FailureInjector | None = None,
                     timer: StepTimer | None = None):
    """Drive ``step_fn(step) -> state`` with restore-and-replay on failure.

    restore_fn() -> step to resume from (reloads state inside).
    Returns (completed_steps, num_restarts).
    """
    step = start_step
    restarts = 0
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            if injector is not None:
                injector.check(step)
            step_fn(step)
            if timer is not None:
                timer.record(step, time.perf_counter() - t0)
            step += 1
        except (InjectedFailure, RuntimeError):
            restarts += 1
            if restarts > max_retries:
                raise
            step = restore_fn()
    return step, restarts
