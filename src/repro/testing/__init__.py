"""Optional-``hypothesis`` shim for the property-based tests.

When hypothesis is installed, this module re-exports the real
``given`` / ``settings`` / ``strategies``.  When it is not (the CI CPU
image ships without it), a minimal deterministic fallback runs each
property against ``max_examples`` seeded pseudo-random draws, so the
property modules keep their full coverage instead of erroring at
collection.

Usage in tests::

    from repro.testing import given, settings, strategies as st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import types
    import zlib

    class _Strategy:
        """A draw function over a seeded ``random.Random``."""

        def __init__(self, draw):
            self.draw = draw

    def _integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _floats(lo: float, hi: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    def _tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

    def _lists(elem: _Strategy, min_size: int = 0,
               max_size: int = 10) -> _Strategy:
        return _Strategy(lambda rng: [
            elem.draw(rng)
            for _ in range(rng.randint(min_size, max_size))])

    strategies = types.SimpleNamespace(
        integers=_integers, booleans=_booleans, floats=_floats,
        sampled_from=_sampled_from, tuples=_tuples, lists=_lists)

    def settings(*, max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats: _Strategy):
        def deco(fn):
            # the TRAILING params are the strategy slots (as in real
            # hypothesis); any leading params stay pytest fixtures
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            strat_names = names[len(names) - len(strats):]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    draws = {nm: s.draw(rng)
                             for nm, s in zip(strat_names, strats)}
                    fn(*args, **kwargs, **draws)
            # hide the strategy-filled params from pytest's fixture
            # resolution
            wrapper.__signature__ = sig.replace(
                parameters=[p for nm, p in sig.parameters.items()
                            if nm not in strat_names])
            return wrapper
        return deco


st = strategies

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "strategies", "st"]
