"""JAX version-compat layer (supported: 0.4.37 through current).

The repo targets one API surface; this module resolves it against whatever
JAX is installed.  Everything that moved between the 0.4.x experimental
namespaces and the newer top-level APIs is imported from here, never from
``jax`` directly:

* ``shard_map``       — ``jax.shard_map`` (new) or
                        ``jax.experimental.shard_map.shard_map`` (0.4.x);
                        the ``check_vma`` kwarg maps onto 0.4.x ``check_rep``.
* ``make_mesh``       — passes ``axis_types=(AxisType.Auto, ...)`` only when
                        the installed JAX has ``jax.sharding.AxisType``.
* ``get_abstract_mesh`` — the ambient trace-time mesh.  New JAX reads its
                        abstract-mesh context; 0.4.x falls back to the mesh
                        installed by :func:`use_mesh` (or, failing that, the
                        classic ``with mesh:`` thread-local physical mesh).
* ``use_mesh``        — context manager the step builders use to make a
                        physical mesh ambient at trace time.
* ``constraint_sharding`` — what to hand ``with_sharding_constraint`` for a
                        PartitionSpec: the bare spec under an abstract-mesh
                        context (new JAX), a ``NamedSharding`` bound to the
                        physical mesh on 0.4.x (where bare specs require the
                        legacy resource environment).
"""
from __future__ import annotations

import contextlib
import threading

import jax

try:                                    # newer JAX: top-level export
    from jax import shard_map as _shard_map_new
except ImportError:                     # 0.4.x: experimental namespace
    _shard_map_new = None
try:
    from jax.experimental.shard_map import shard_map as _shard_map_exp
except ImportError:                     # future JAX may drop the old path
    _shard_map_exp = None

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
HAS_ABSTRACT_MESH_CTX = hasattr(jax.sharding, "get_abstract_mesh")


def jax_version() -> tuple[int, ...]:
    return tuple(int(p) for p in jax.__version__.split(".")[:3]
                 if p.isdigit())


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """Version-stable shard_map.  ``check_vma=None`` keeps the library
    default; an explicit bool maps onto 0.4.x ``check_rep``."""
    if _shard_map_new is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
    if _shard_map_exp is None:          # pragma: no cover - defensive
        raise ImportError("no shard_map implementation in this JAX")
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


def axis_size(name) -> int:
    """``jax.lax.axis_size`` (newer JAX) with a 0.4.x fallback:
    ``psum(1, name)`` constant-folds to the bound axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis_types when the API supports them."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if HAS_AXIS_TYPES:
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


_AMBIENT = threading.local()            # 0.4.x fallback mesh context


def get_abstract_mesh():
    """The ambient trace-time mesh, or None when outside any mesh context.

    New JAX returns the AbstractMesh from its context; on 0.4.x this is the
    physical mesh installed by :func:`use_mesh` (or a legacy ``with mesh:``
    block).  Callers only rely on ``axis_names`` / ``shape``, which both
    mesh flavors provide.
    """
    if HAS_ABSTRACT_MESH_CTX:
        m = jax.sharding.get_abstract_mesh()
        return m if m is not None and m.axis_names else None
    m = getattr(_AMBIENT, "mesh", None)
    if m is not None:
        return m
    from jax._src import mesh as _mesh_lib
    pm = _mesh_lib.thread_resources.env.physical_mesh
    return None if pm.empty else pm


@contextlib.contextmanager
def use_mesh(mesh):
    """Make physical ``mesh`` ambient for sharding hints at trace time."""
    if HAS_ABSTRACT_MESH_CTX:
        with jax.sharding.use_abstract_mesh(mesh.abstract_mesh):
            yield
        return
    prev = getattr(_AMBIENT, "mesh", None)
    _AMBIENT.mesh = mesh
    try:
        yield
    finally:
        _AMBIENT.mesh = prev


def constraint_sharding(mesh, spec):
    """Resolve a PartitionSpec against the ambient mesh for
    ``with_sharding_constraint``."""
    if isinstance(mesh, jax.sharding.Mesh):
        return jax.sharding.NamedSharding(mesh, spec)
    return spec                          # abstract mesh: context resolves it
