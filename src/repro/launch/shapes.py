"""Assigned input-shape cells and abstract input specs (no allocation).

Every (arch x shape) cell resolves to ShapeDtypeStruct stand-ins for the
exact arrays the lowered step consumes:

  train_4k    -> train_step(state, batch)          seq 4096,   gbatch 256
  prefill_32k -> prefill_fn(params, batch)         seq 32768,  gbatch 32
  decode_32k  -> serve_step(params, caches, tok)   KV 32768,   gbatch 128
  long_500k   -> serve_step(params, caches, tok)   KV 524288,  gbatch 1

``long_500k`` is only valid for sub-quadratic archs (cfg.subquadratic);
pure full-attention archs are skipped (DESIGN.md §5).  Whisper's encoder
context is capped at its architectural maximum of 1500 frames for decode
cells; train/prefill apply the cell's seq_len to both encoder frames and
decoder tokens (backbone stress per the assignment).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.transformer import init_decode_state

WHISPER_MAX_ENC = 1500


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_is_applicable(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode skipped"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, cell: ShapeCell, with_labels=True) -> dict:
    b, s = cell.global_batch, cell.seq_len
    batch = {}
    if with_labels:
        batch["labels"] = _sds((b, s), jnp.int32)
    if cfg.frontend == "vision_stub":
        batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "audio_stub":
        batch["tokens"] = _sds((b, s), jnp.int32)
        enc = min(s, WHISPER_MAX_ENC) if cell.kind == "decode" else s
        batch["frames"] = _sds((b, enc, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
    return batch


def decode_specs(cfg: ArchConfig, cell: ShapeCell):
    """(caches, tokens, pos) abstract specs for serve_step."""
    b, s = cell.global_batch, cell.seq_len
    enc_len = min(s, WHISPER_MAX_ENC) if cfg.encoder_layers else 0
    caches = jax.eval_shape(functools.partial(
        init_decode_state, cfg, b, s, enc_len=enc_len))
    tokens = _sds((b,), jnp.int32)
    pos = _sds((), jnp.int32)
    return caches, tokens, pos


def input_specs(cfg: ArchConfig, cell_name: str):
    """All abstract inputs for the cell's step function."""
    cell = SHAPES[cell_name]
    if cell.kind == "train":
        return {"batch": batch_specs(cfg, cell)}
    if cell.kind == "prefill":
        return {"batch": batch_specs(cfg, cell, with_labels=False)}
    caches, tokens, pos = decode_specs(cfg, cell)
    return {"caches": caches, "tokens": tokens, "pos": pos}
