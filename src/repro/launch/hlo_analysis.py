"""HLO text analyzer: loop-aware FLOP / byte / collective accounting.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports any scanned program (layer stacks, SSD chunking, flash
attention).  This module parses the optimized HLO text instead:

  * per computation: a symbol table (op name -> result shape) is built
    first, because optimized HLO prints operands as bare names;
  * FLOPs from dot/convolution result + contraction shapes,
  * HBM bytes from top-level op operand/result sizes (fusion = its inputs
    + outputs; the fused body's interior ops are register traffic),
  * collective bytes from all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute operand sizes, broken out per op kind,
  * call graph: while-loop bodies are multiplied by their trip count
    (``known_trip_count`` from backend_config), fusion bodies contribute
    FLOPs (dots inside fusions are real) but not bytes,
  * shapes in SPMD-partitioned modules are per-device shard shapes, so all
    results are per-device quantities.

Validated against hand-counted references in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "s4": 1,
    "u4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}/ ]+?)\s+"
    r"([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{"n":\s*"(\d+)"')
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")


def _header_name(line: str) -> str | None:
    """Computation headers start at column 0 and end with '{'.  (A regex on
    the parameter list breaks on tuple-typed params' nested parens.)"""
    if not line or line[0].isspace() or not line.rstrip().endswith("{"):
        return None
    if "(" not in line or line.startswith("HloModule"):
        return None
    m = _COMP_NAME_RE.match(line)
    return m.group(1) if m else None
_NAME_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = {
    "all-gather", "all-gather-start", "all-reduce", "all-reduce-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start", "ragged-all-to-all",
}

# Ops whose operand+result sizes we count as HBM traffic.  Restricted to
# fusion boundaries: a TPU backend fuses elementwise / broadcast / reshape
# chains into their consumers, but the CPU HLO we compile leaves many of
# them standalone - counting those would overstate HBM bytes severalfold.
_MEM_OPS = COLLECTIVES | {
    "fusion", "dot", "convolution", "copy", "dynamic-update-slice",
    "gather", "scatter", "sort", "rng-bit-generator", "custom-call",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_count: int = 0
    # per-collective-op byte totals, e.g. {"all-gather": 1.2e9}
    coll_by: dict = dataclasses.field(default_factory=dict)
    # (callee, multiplier, kind) edges
    calls: list = dataclasses.field(default_factory=list)
    # name -> result type string (symbol table)
    syms: dict = dataclasses.field(default_factory=dict)


def _operand_str(line: str, opname: str) -> str:
    """The text inside op's first parenthesized operand list."""
    m = re.search(re.escape(opname) + r"\(", line)
    if not m:
        return ""
    start = m.end()
    depth = 1
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[start:i]
    return line[start:]


def _operand_bytes(args: str, syms: dict) -> int:
    """Sum operand sizes: inline shapes if printed, else symbol lookup."""
    inline = _shape_bytes(args)
    if inline:
        return inline
    total = 0
    for name in _NAME_RE.findall(args):
        t = syms.get(name)
        if t:
            total += _shape_bytes(t)
    return total


def _dot_flops(line: str, type_str: str, syms: dict) -> float:
    """2 * prod(result_dims) * contraction_size for a dot/convolution."""
    out_dims = _first_dims(type_str) or [1]
    out_elems = math.prod(out_dims)
    args = _operand_str(line, "convolution" if "convolution(" in line
                        else "dot")
    # operand shapes: inline if printed, else from the symbol table
    shapes = _SHAPE_RE.findall(args)
    op_dims = [[int(d) for d in dims.split(",") if d] for _, dims in shapes]
    if not op_dims:
        names = _NAME_RE.findall(args)
        op_dims = [_first_dims(syms.get(n, "")) for n in names]
    if "convolution(" in line:
        if len(op_dims) >= 2 and op_dims[1]:
            rhs = op_dims[1]
            k = math.prod(rhs) // max(rhs[-1], 1)
            return 2.0 * out_elems * k
        return 2.0 * out_elems
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not cm or not op_dims or not op_dims[0]:
        return 2.0 * out_elems
    lhs_dims = op_dims[0]
    contract = 1
    for ci in cm.group(1).split(","):
        if ci and int(ci) < len(lhs_dims):
            contract *= lhs_dims[int(ci)]
    return 2.0 * out_elems * contract


def parse_hlo(text: str) -> dict[str, CompStats]:
    lines = text.splitlines()
    # pass 1: each computation's ROOT op (to spot fused in-place updates)
    roots: dict[str, str] = {}
    cur_name = None
    for line in lines:
        name = _header_name(line)
        if name is not None:
            cur_name = name
            continue
        if cur_name and line.lstrip().startswith("ROOT "):
            m = _OP_RE.match(line)
            if m:
                roots[cur_name] = m.group(3)

    comps: dict[str, CompStats] = {}
    cur: CompStats | None = None
    entry: str | None = None
    for line in lines:
        name = _header_name(line)
        if name is not None:
            cur = comps.setdefault(name, CompStats())
            if line.startswith("ENTRY"):
                entry = name
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        res_name, type_str, opname = m.groups()
        cur.syms[res_name] = type_str
        fusion_root = ""
        if opname == "fusion":
            cm0 = re.search(r"calls=%?([\w.\-]+)", line)
            if cm0:
                fusion_root = roots.get(cm0.group(1), "")
        if opname in ("dot", "convolution"):
            cur.flops += _dot_flops(line, type_str, cur.syms)
        if opname in COLLECTIVES:
            # operand bytes (per-device shard shapes in SPMD modules)
            args = _operand_str(line, opname)
            b = _operand_bytes(args, cur.syms)
            cur.coll_bytes += b
            cur.coll_count += 1
            key = opname.removesuffix("-start")
            cur.coll_by[key] = cur.coll_by.get(key, 0.0) + b
        if opname in _MEM_OPS:
            args = _operand_str(line, opname)
            in_b = _operand_bytes(args, cur.syms)
            out_b = _shape_bytes(type_str)
            if (opname == "dynamic-update-slice"
                    or (opname == "fusion"
                        and ("dynamic-update-slice" in res_name
                             or fusion_root == "dynamic-update-slice"))):
                # in-place slice update: with buffer aliasing only the
                # updated region moves, not the full carried buffer
                ops_b = [_shape_bytes(cur.syms.get(n, ""))
                         for n in _NAME_RE.findall(args)]
                big = max(ops_b, default=0)
                cur.bytes += max(in_b - big, 0) + max(out_b - big, 0)
            else:
                cur.bytes += in_b + out_b
        if opname == "while":
            mult = 1
            tm = _TRIP_RE.search(line)
            if tm:
                mult = int(tm.group(1))
            bm = re.search(r"body=%?([\w.\-]+)", line)
            if bm:
                cur.calls.append((bm.group(1), mult, "while"))
            cm_ = re.search(r"condition=%?([\w.\-]+)", line)
            if cm_:
                cur.calls.append((cm_.group(1), mult + 1, "cond"))
        elif opname in ("fusion", "call", "custom-call", "reduce", "scatter",
                        "map", "sort", "select-and-scatter", "conditional"):
            for cm2 in re.finditer(
                    r"(?:calls|to_apply|called_computations=\{)=?%?"
                    r"([\w.\-]+)", line):
                cur.calls.append((cm2.group(1), 1, opname))
    comps["__entry__"] = comps.get(entry, CompStats()) if entry else CompStats()
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def aggregate(comps: dict, root: str | None = None,
              _memo: dict | None = None) -> CompStats:
    """Recursive totals from the entry computation, loop-aware.

    Fusion-kind edges contribute FLOPs/collectives only (the fused body's
    interior loads/stores are not HBM traffic); while/call edges contribute
    everything x trip count.
    """
    if root is None:
        root = comps.get("__entry_name__")
    memo = _memo if _memo is not None else {}

    def rec(name: str) -> tuple[float, float, float, int, dict]:
        if name in memo:
            return memo[name]
        st = comps.get(name)
        if st is None or not isinstance(st, CompStats):
            return (0.0, 0.0, 0.0, 0, {})
        memo[name] = (0.0, 0.0, 0.0, 0, {})  # cycle guard
        f, b, c, n = st.flops, st.bytes, st.coll_bytes, st.coll_count
        by = dict(st.coll_by)
        for callee, mult, kind in st.calls:
            if callee is None:
                continue
            cf, cb, cc, cn, cby = rec(callee)
            f += mult * cf
            c += mult * cc
            n += mult * cn
            if kind not in ("fusion", "reduce", "scatter", "map", "sort",
                            "select-and-scatter"):
                b += mult * cb
            for k, v in cby.items():
                by[k] = by.get(k, 0.0) + mult * v
        memo[name] = (f, b, c, n, by)
        return memo[name]

    f, b, c, n, by = rec(root) if root else (0.0, 0.0, 0.0, 0, {})
    out = CompStats(flops=f, bytes=b, coll_bytes=c, coll_by=by)
    out.coll_count = n
    return out


def analyze_hlo_text(text: str) -> dict:
    comps = parse_hlo(text)
    total = aggregate(comps)
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "collective_bytes": total.coll_bytes,
        "collective_count": total.coll_count,
        "collective_by_op": total.coll_by,
    }
