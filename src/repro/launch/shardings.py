"""Sharding rules: parameters, optimizer state, batches, decode caches.

Auto-spec assigns mesh axes to tensor dims from an ordered preference list,
skipping any assignment that does not divide evenly (so GQA kv-heads fall
back to head_dim TP, batch=1 falls back to sequence sharding, etc.).

Posture (baseline):
  * params: TP over `model` on the widest "parallel" dim (heads / d_ff /
    experts / head_dim), FSDP over `data` on a remaining dim when divisible.
  * optimizer state: same spec as its parameter (ZeRO via GSPMD).
  * batch: global batch over (pod, data).
  * decode KV caches: batch over (pod, data) when divisible, sequence dim
    over `model` (distributed flash-decoding); otherwise sequence over
    everything available.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP_AXES = ("pod", "data")
TP_AXIS = "model"


def _axes_size(mesh_shape: dict, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh_shape.get(a, 1) for a in axes]))


def pick_spec(shape: tuple[int, ...], prefs: list[tuple[int, tuple[str, ...]]],
              mesh_shape: dict) -> P:
    """Assign mesh axes to dims by priority, honoring divisibility."""
    spec: list[Any] = [None] * len(shape)
    used: set[str] = set()
    for dim, axes in prefs:
        axes = tuple(a for a in axes if a in mesh_shape)
        if not axes or any(a in used for a in axes) or dim >= len(shape):
            continue
        if spec[dim] is not None:
            continue
        if shape[dim] % _axes_size(mesh_shape, axes) != 0:
            continue
        spec[dim] = axes if len(axes) > 1 else axes[0]
        used.update(axes)
    return P(*spec)


# preference tables keyed by parameter leaf name; dims are offsets from the
# *end* of the shape so stacked [count, ...] segment params reuse the rules.
_PARAM_PREFS = {
    # attention projections [d, h|hkv, hd]: heads -> head_dim -> fsdp(d)
    "wq": [(-2, (TP_AXIS,)), (-1, (TP_AXIS,)), (-3, ("data",))],
    "wk": [(-2, (TP_AXIS,)), (-1, (TP_AXIS,)), (-3, ("data",))],
    "wv": [(-2, (TP_AXIS,)), (-1, (TP_AXIS,)), (-3, ("data",))],
    "wo": [(-3, (TP_AXIS,)), (-2, (TP_AXIS,)), (-1, ("data",))],
    # MLP [d, f] / [f, d]
    "w_gate": [(-1, (TP_AXIS,)), (-2, ("data",))],
    "w_up": [(-1, (TP_AXIS,)), (-2, ("data",))],
    "w_down": [(-2, (TP_AXIS,)), (-1, ("data",))],
    # embedding [V, d]: vocab TP + fsdp on d
    "embed": [(-2, (TP_AXIS,)), (-1, ("data",))],
    # ssm / rglru projections [d, p]; per-stream mamba2 weights shard their
    # own output dims (B/C/dt streams are small -> replicate)
    "in_proj": [(-1, (TP_AXIS,)), (-2, ("data",))],
    "w_z": [(-1, (TP_AXIS,)), (-2, ("data",))],
    "w_xin": [(-1, (TP_AXIS,)), (-2, ("data",))],
    "w_b": [(-2, ("data",))],
    "w_c": [(-2, ("data",))],
    "w_dt": [(-1, (TP_AXIS,))],
    "conv_wx": [(-1, (TP_AXIS,))],
    "conv_bx": [(-1, (TP_AXIS,))],
    "out_proj": [(-2, (TP_AXIS,)), (-1, ("data",))],
    "w_x": [(-1, (TP_AXIS,)), (-2, ("data",))],
    "w_gate_branch": [(-1, (TP_AXIS,)), (-2, ("data",))],
    "w_r": [(-1, (TP_AXIS,))],
    "w_i": [(-1, (TP_AXIS,))],
    "w_out": [(-2, (TP_AXIS,)), (-1, ("data",))],
    "conv_w": [(-1, (TP_AXIS,))],
    "conv_b": [(-1, (TP_AXIS,))],
    "router": [],
}

_MOE_PREFS = {
    # expert-parallel stacks [E, d, f] / [E, f, d]
    "w_gate": [(-3, (TP_AXIS,)), (-2, ("data",))],
    "w_up": [(-3, (TP_AXIS,)), (-2, ("data",))],
    "w_down": [(-3, (TP_AXIS,)), (-2, ("data",))],
}


def param_pspec(path, leaf, mesh_shape: dict) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf_name = names[-1]
    in_moe = "moe" in names
    table = _MOE_PREFS if (in_moe and leaf_name in _MOE_PREFS) else _PARAM_PREFS
    prefs = table.get(leaf_name, [])
    nd = len(leaf.shape)
    prefs_abs = [(nd + d if d < 0 else d, a) for d, a in prefs
                 if -nd <= d < nd]
    return pick_spec(leaf.shape, prefs_abs, mesh_shape)


def param_shardings(abstract_tree, mesh: Mesh):
    mesh_shape = dict(mesh.shape)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(path, leaf, mesh_shape)), abstract_tree)


def batch_pspec(shape: tuple[int, ...], mesh_shape: dict) -> P:
    """Token/label/embeds batches: batch over (pod, data)."""
    prefs = [(0, DP_AXES), (0, ("data",))]
    return pick_spec(shape, prefs, mesh_shape)


def batch_shardings(batch_tree, mesh: Mesh):
    mesh_shape = dict(mesh.shape)
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_pspec(leaf.shape, mesh_shape)),
        batch_tree)


def cache_pspec(shape: tuple[int, ...], mesh_shape: dict,
                seq_axis_joint: bool = False) -> P:
    """Decode caches.

    KV tensors are [count, B, L, hkv, hd]; ssm/rglru states are
    [count, B, ...].  Batch gets (pod, data) when divisible; the longest
    remaining dim gets `model` (KV length / state width).
    """
    nd = len(shape)
    prefs: list[tuple[int, tuple[str, ...]]] = []
    if nd >= 2:
        prefs.append((1, DP_AXES))
        prefs.append((1, ("data",)))
    if nd >= 3:
        # the sequence / width dim: prefer the largest dim after batch
        cand = int(np.argmax(shape[2:])) + 2
        if seq_axis_joint:
            prefs.append((cand, (TP_AXIS, "data")))
        prefs.append((cand, (TP_AXIS,)))
    return pick_spec(shape, prefs, mesh_shape)


def cache_shardings(cache_tree, mesh: Mesh, seq_axis_joint: bool = False):
    mesh_shape = dict(mesh.shape)
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, cache_pspec(leaf.shape, mesh_shape, seq_axis_joint)),
        cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
