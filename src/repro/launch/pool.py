"""Worker pool: several MS-BFS engines behind ONE submit surface.

One ``DynamicBatcher`` keeps one engine busy; a pool keeps several —
the serving analogue of ScalaBFS running 64 processing elements against
32 HBM pseudo-channels, where aggregate throughput comes from many
independent workers, not one wider one.  ``WorkerPool`` owns one
:class:`~repro.launch.dynbatch.DynamicBatcher` per engine (each with its
own bounded queue, worker thread, and optionally its own
``EngineSupervisor``) and routes every ``submit`` to the least-loaded
worker:

* Routing is JOIN-SHORTEST-QUEUE on ``DynamicBatcher.backlog()``
  (queued + cut-but-unfinished requests), with a round-robin tiebreak so
  an idle pool still spreads waves across engines instead of pinning
  everything to worker 0.
* SLO semantics (``deadline=`` / ``priority=``) pass straight through —
  each worker cuts its own waves urgency-first, and ``stats()`` merges
  the per-worker SLO accounting into one pool-wide miss rate.
* Backpressure composes: a non-blocking submit that finds EVERY worker's
  queue full raises ``QueueFull``; a blocking submit waits on the least
  backlogged worker.
* Engines must be INDEPENDENT (their own runner instances — device graph
  arrays may be shared, traversal state is per-runner).  Threads over
  local ``MultiSourceBFSRunner`` instances today; ``DistributedBFS``
  meshes slot in unchanged once multi-host meshes land (ROADMAP item 3).

Fake-clock testing works like the single batcher: construct with
``clock=`` (workers then run no threads) and drive with :meth:`pump` /
:meth:`flush`.
"""
from __future__ import annotations

import numpy as np

from repro.launch.dynbatch import (BFSFuture, DynamicBatcher, QueueFull,
                                   WaveStats)


class WorkerPool:
    """Route single-root BFS queries across a pool of per-engine batchers.

    ``engines``: independent engine instances (one worker each).  Every
    other keyword is forwarded to each worker's ``DynamicBatcher`` —
    ``window``, ``max_batch``, ``pipeline``, ``slo_margin``, ``clock``,
    etc., so the pool's workers are homogeneous by construction.
    """

    def __init__(self, engines, *, out_deg: np.ndarray | None = None,
                 **batcher_kw):
        engines = list(engines)
        if not engines:
            raise ValueError("WorkerPool needs at least one engine")
        self.workers: list[DynamicBatcher] = [
            DynamicBatcher(e, out_deg=out_deg, **batcher_kw)
            for e in engines]
        self._rr = 0                      # round-robin tiebreak cursor
        self._closed = False

    # -- client side ------------------------------------------------------

    def _ranked(self) -> list[int]:
        """Worker indices by (backlog, round-robin distance) ascending."""
        n = len(self.workers)
        loads = [w.backlog() for w in self.workers]
        order = sorted(range(n),
                       key=lambda i: (loads[i], (i - self._rr) % n))
        self._rr = (order[0] + 1) % n
        return order

    def submit(self, root: int, *, block: bool = True,
               timeout: float | None = None, deadline: float | None = None,
               priority: int = 0) -> BFSFuture:
        """Enqueue one query on the least-backlogged worker.

        Non-blocking submits fail over: if the chosen worker's queue is
        full the next-least-loaded one is tried, and ``QueueFull`` only
        propagates when EVERY worker is at capacity.  Blocking submits
        wait on the least-loaded worker (its thread is draining it).
        """
        order = self._ranked()
        if block:
            return self.workers[order[0]].submit(
                root, block=True, timeout=timeout, deadline=deadline,
                priority=priority)
        last: QueueFull | None = None
        for i in order:
            try:
                return self.workers[i].submit(
                    root, block=False, deadline=deadline,
                    priority=priority)
            except QueueFull as exc:
                last = exc
        raise QueueFull(
            f"all {len(self.workers)} worker queues full") from last

    def backlog(self) -> int:
        return sum(w.backlog() for w in self.workers)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc == (None, None, None))

    # -- scheduler (fake-clock mode) --------------------------------------

    def pump(self, force: bool = False) -> list[WaveStats]:
        """Dispatch at most one due wave PER WORKER (fake-clock mode)."""
        out = []
        for w in self.workers:
            ws = w.pump(force)
            if ws is not None:
                out.append(ws)
        return out

    def flush(self) -> list[WaveStats]:
        """Dispatch everything pending on every worker, deadlines
        ignored."""
        return [ws for w in self.workers for ws in w.flush()]

    def close(self, drain: bool = True, timeout: float | None = None):
        """Close every worker (serially; each drains its own queue)."""
        self._closed = True
        for w in self.workers:
            w.close(drain=drain, timeout=timeout)

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict:
        """Pool-wide aggregate: exact totals summed across workers,
        latency percentiles over the POOLED per-wave latencies (so one
        slow worker shows up in the pool's p99, not just its own), plus
        each worker's own stats under ``per_worker``.
        """
        per = [w.stats() for w in self.workers]
        lats: list[float] = []
        for w in self.workers:
            with w._cond:
                lats.extend(l for wave in w.waves for l in wave.latencies)
        out = dict(
            workers=len(self.workers),
            waves=sum(p["waves"] for p in per),
            errors=sum(p["errors"] for p in per),
            requests=sum(p["requests"] for p in per),
            busy_seconds=round(sum(p["busy_seconds"] for p in per), 4),
            engine_idle_seconds=round(
                sum(p["engine_idle_seconds"] for p in per), 4),
            pipeline=any(p["pipeline"] for p in per),
        )
        n_failed = sum(p.get("requests_failed", 0) for p in per)
        if n_failed:
            out["requests_failed"] = n_failed
        n_slo = sum(p.get("slo_requests", 0) for p in per)
        if n_slo:
            n_miss = sum(p.get("slo_misses", 0) for p in per)
            out.update(slo_requests=n_slo, slo_misses=n_miss,
                       slo_miss_rate=round(n_miss / n_slo, 4))
        if any("traversed_edges" in p for p in per):
            trav = sum(p.get("traversed_edges", 0) for p in per)
            busy = sum(p["busy_seconds"] for p in per)
            # engine-busy TEPS: edges per second of ENGINE time summed
            # across workers — wall-clock delivered throughput is the
            # harness's job (it knows the stream's makespan, we don't)
            out.update(traversed_edges=int(trav),
                       aggregate_teps=round(trav / max(busy, 1e-12), 1))
        if lats:
            a = np.asarray(lats, np.float64)
            out.update(
                latency_mean=round(float(a.mean()), 4),
                latency_p50=round(float(np.percentile(a, 50)), 4),
                latency_p99=round(float(np.percentile(a, 99)), 4),
                latency_p999=round(float(np.percentile(a, 99.9)), 4),
            )
        if any("fault_tolerance" in p for p in per):
            out["fault_tolerance"] = [p.get("fault_tolerance")
                                      for p in per]
        out["per_worker"] = per
        return out
