"""Worker pool: several MS-BFS engines behind ONE submit surface.

One ``DynamicBatcher`` keeps one engine busy; a pool keeps several —
the serving analogue of ScalaBFS running 64 processing elements against
32 HBM pseudo-channels, where aggregate throughput comes from many
independent workers, not one wider one.  ``WorkerPool`` owns one
:class:`~repro.launch.dynbatch.DynamicBatcher` per engine (each with its
own bounded queue, worker thread, and optionally its own
``EngineSupervisor``) and routes every ``submit`` to the least-loaded
worker:

* Routing is JOIN-SHORTEST-QUEUE on ``DynamicBatcher.backlog()``
  (queued + cut-but-unfinished requests), with a round-robin tiebreak so
  an idle pool still spreads waves across engines instead of pinning
  everything to worker 0.
* SLO semantics (``deadline=`` / ``priority=``) pass straight through —
  each worker cuts its own waves urgency-first, and ``stats()`` merges
  the per-worker SLO accounting into one pool-wide miss rate.
* Backpressure composes: a non-blocking submit that finds EVERY worker's
  queue full raises ``QueueFull``; a blocking submit waits on the least
  backlogged worker.
* HEALTH STATE MACHINE: each worker is HEALTHY, SUSPECT, or EVICTED.
  Consecutive engine-failure waves (quarantine-only waves don't count)
  drive HEALTHY -> SUSPECT (ranked last for new work) at
  ``suspect_after`` and SUSPECT -> EVICTED at ``evict_after``; eviction
  drains the worker's queue and REDISPATCHES every queued and failing
  in-flight future to survivors (respecting their ``max_pending``), so
  a permanently dead engine costs its requests a detour, not an error.
  A successful wave resets the streak and re-admits a SUSPECT worker.
  :meth:`probe_evicted` (manual, or periodic via ``probe_interval``)
  re-runs a probe traversal on each evicted engine and rebuilds a fresh
  worker around it when it answers again.
* ADMISSION CONTROL (``shed=True``): a deadline request is refused with
  a typed ``Overloaded`` when even the least-delayed admissible worker's
  estimated queue delay (EWMA wave service x waves of backlog) already
  exceeds the SLO — the reject lands in well under one wave time,
  protecting the latency of everything already queued.
* Engines must be INDEPENDENT (their own runner instances — device graph
  arrays may be shared, traversal state is per-runner).  Threads over
  local ``MultiSourceBFSRunner`` instances today; ``DistributedBFS``
  meshes slot in unchanged once multi-host meshes land (ROADMAP item 3).

Fake-clock testing works like the single batcher: construct with
``clock=`` (workers then run no threads) and drive with :meth:`pump` /
:meth:`flush` (flush loops until redispatches quiesce); call
:meth:`probe_evicted` yourself in lieu of the probe thread.
"""
from __future__ import annotations

import functools
import threading
import time

import numpy as np

from repro.ft.supervisor import (DETERMINISTIC, RequestQuarantined,
                                 classify_fault)
from repro.launch.dynbatch import (BatcherClosed, BFSFuture, DynamicBatcher,
                                   Overloaded, QueueFull, WaveStats)

HEALTHY, SUSPECT, EVICTED = "healthy", "suspect", "evicted"
HEALTH_STATES = (HEALTHY, SUSPECT, EVICTED)


def _redispatchable(exc: BaseException) -> bool:
    """Should a future failing with ``exc`` be retried on ANOTHER worker?

    Deterministic (input-shaped) faults and quarantined roots would fail
    identically everywhere — redispatching them just poisons a healthy
    worker's streak.  Transient faults (timeouts, kernel faults,
    integrity violations, generic runtime errors) are the worker's
    problem, not the request's: those travel.
    """
    if isinstance(exc, (RequestQuarantined, BatcherClosed, Overloaded)):
        return False
    return classify_fault(exc) != DETERMINISTIC


class WorkerPool:
    """Route single-root BFS queries across a pool of per-engine batchers.

    ``engines``: independent engine instances (one worker each).  Every
    other keyword is forwarded to each worker's ``DynamicBatcher`` —
    ``window``, ``max_batch``, ``pipeline``, ``slo_margin``, ``clock``,
    etc., so the pool's workers are homogeneous by construction.

    ``evict_after`` / ``suspect_after``: consecutive engine-failure waves
    before a worker is evicted / marked suspect (suspect defaults to half
    the evict threshold, at least 1).  ``shed=True`` turns on pool-level
    admission control.  ``probe_interval`` (seconds, real time) starts a
    daemon probe thread that periodically tries to re-admit evicted
    workers; ``engine_factory(idx) -> engine`` (optional) builds a
    REPLACEMENT engine at re-admission instead of reusing the old object.
    """

    def __init__(self, engines, *, out_deg: np.ndarray | None = None,
                 evict_after: int = 3, suspect_after: int | None = None,
                 shed: bool = False, probe_interval: float | None = None,
                 engine_factory=None, **batcher_kw):
        engines = list(engines)
        if not engines:
            raise ValueError("WorkerPool needs at least one engine")
        if evict_after < 1:
            raise ValueError(f"need evict_after >= 1, got {evict_after}")
        self.evict_after = int(evict_after)
        self.suspect_after = (max(1, self.evict_after // 2)
                              if suspect_after is None
                              else int(suspect_after))
        if not (1 <= self.suspect_after <= self.evict_after):
            raise ValueError(
                f"need 1 <= suspect_after <= evict_after, got "
                f"{self.suspect_after} vs {self.evict_after}")
        self.shed = bool(shed)
        self.engine_factory = engine_factory
        self._engines = engines
        self._batcher_kw = dict(batcher_kw, out_deg=out_deg)
        self.workers: list[DynamicBatcher] = [
            DynamicBatcher(
                e, failure_handler=functools.partial(
                    self._on_request_failure, i),
                **self._batcher_kw)
            for i, e in enumerate(engines)]
        self._health: list[str] = [HEALTHY] * len(engines)
        self._retired: list[DynamicBatcher] = []   # abandoned after probe
        self._rr = 0                      # round-robin tiebreak cursor
        self._lock = threading.RLock()    # health transitions + counters
        self._closed = False
        self._n_evictions = 0
        self._n_redispatches = 0
        self._n_shed = 0                  # pool-level admission rejects
        self._n_probes = 0
        self._n_probe_failures = 0
        self._probe_stop = threading.Event()
        self._probe_thread: threading.Thread | None = None
        if probe_interval is not None:
            if probe_interval <= 0:
                raise ValueError(
                    f"need probe_interval > 0, got {probe_interval}")
            self._probe_thread = threading.Thread(
                target=self._probe_loop, args=(float(probe_interval),),
                name="pool-probe", daemon=True)
            self._probe_thread.start()

    # -- health state machine ---------------------------------------------

    def health(self) -> list[str]:
        """Per-worker health snapshot (``HEALTH_STATES`` values)."""
        with self._lock:
            self._refresh_health_locked()
            return list(self._health)

    def _refresh_health_locked(self):
        # SUSPECT -> HEALTHY re-admission: a successful wave reset the
        # worker's failure streak (eviction never auto-reverses — only
        # probe_evicted readmits)
        for i, h in enumerate(self._health):
            if h == SUSPECT and self.workers[i].consecutive_failures == 0:
                self._health[i] = HEALTHY

    def _on_request_failure(self, idx: int, fut: BFSFuture,
                            exc: BaseException) -> bool:
        """Worker ``idx``'s failure handler (runs on its finisher thread).

        Notes the failure against the health state machine, evicts at the
        threshold (draining the queue to survivors), and decides whether
        THIS future travels: True hands ownership to the pool (the
        future was requeued on a survivor), False lets the worker fail it
        normally.
        """
        evict = False
        with self._lock:
            if not self._closed and self._health[idx] != EVICTED:
                streak = self.workers[idx].consecutive_failures
                if streak >= self.evict_after:
                    self._health[idx] = EVICTED
                    self._n_evictions += 1
                    evict = True
                elif streak >= self.suspect_after:
                    self._health[idx] = SUSPECT
        if evict:
            self._drain_evicted(idx)
        if self._closed or not _redispatchable(exc):
            return False
        return self._redispatch(fut, exclude=idx)

    def _drain_evicted(self, idx: int):
        """Move an evicted worker's queued futures to survivors; anything
        that cannot be placed fails typed rather than hanging."""
        for f in self.workers[idx].cancel_pending():
            if not self._redispatch(f, exclude=idx):
                f._fail(Overloaded(
                    f"worker {idx} evicted and no surviving worker "
                    f"could absorb root {f.root}"))

    def _redispatch(self, fut: BFSFuture, exclude: int | None = None
                    ) -> bool:
        """Requeue a future on the best admissible worker.  Bounded: a
        future hops at most workers-1 times, so a pool-wide outage fails
        requests instead of circulating them forever."""
        hops = getattr(fut, "_redispatches", 0)
        if hops >= max(len(self.workers) - 1, 1):
            return False
        for i in self._ranked():
            if i == exclude:
                continue
            try:
                self.workers[i]._submit_future(fut)
            except (QueueFull, BatcherClosed):
                continue
            fut._redispatches = hops + 1
            with self._lock:
                self._n_redispatches += 1
            return True
        return False

    def _probe_loop(self, interval: float):
        while not self._probe_stop.wait(interval):
            if self._closed:
                return
            try:
                self.probe_evicted()
            except Exception:
                pass               # probe must never kill its own thread

    def _probe_engine(self, eng) -> bool:
        """One probe traversal from root 0: does the engine answer?"""
        try:
            if hasattr(eng, "run_wave"):   # EngineSupervisor facade
                wave = eng.run_wave(np.asarray([0], np.int64))
                return wave.n_failed == 0
            eng.run_batch(np.asarray([0], np.int64))
            return True
        except Exception:
            return False

    def probe_evicted(self) -> int:
        """Try to re-admit every EVICTED worker; returns how many came
        back.  Each probe runs one traversal on the (possibly rebuilt)
        engine OUTSIDE the serving path; success swaps in a fresh
        ``DynamicBatcher`` — the old one is abandoned unjoined, because a
        wedged engine call would hang any attempt to join its threads.
        """
        with self._lock:
            targets = [i for i, h in enumerate(self._health)
                       if h == EVICTED]
        readmitted = 0
        for idx in targets:
            if self._closed:
                break
            with self._lock:
                self._n_probes += 1
            eng = self._engines[idx]
            if self.engine_factory is not None:
                try:
                    eng = self.engine_factory(idx)
                except Exception:
                    with self._lock:
                        self._n_probe_failures += 1
                    continue
            if not self._probe_engine(eng):
                with self._lock:
                    self._n_probe_failures += 1
                continue
            old = self.workers[idx]
            for f in old.cancel_pending():   # raced in before eviction
                if not self._redispatch(f, exclude=idx):
                    f._fail(Overloaded(
                        f"worker {idx} rebuilt and no other worker "
                        f"could absorb root {f.root}"))
            with old._cond:
                old._closed = True
                old._cond.notify_all()
            self._retired.append(old)
            self._engines[idx] = eng
            fresh = DynamicBatcher(
                eng, failure_handler=functools.partial(
                    self._on_request_failure, idx),
                **self._batcher_kw)
            with self._lock:
                self.workers[idx] = fresh
                self._health[idx] = HEALTHY
            readmitted += 1
        return readmitted

    # -- client side ------------------------------------------------------

    def _ranked(self) -> list[int]:
        """Admissible worker indices by (suspect-last, backlog,
        round-robin distance) ascending.  EVICTED and closed workers are
        excluded — nothing new is ever routed to them."""
        n = len(self.workers)
        with self._lock:
            self._refresh_health_locked()
            elig = [i for i in range(n)
                    if self._health[i] != EVICTED
                    and not self.workers[i]._closed]
            suspect = {i for i in elig if self._health[i] == SUSPECT}
        if not elig:
            return []
        loads = {i: self.workers[i].backlog() for i in elig}
        order = sorted(elig, key=lambda i: (i in suspect, loads[i],
                                            (i - self._rr) % n))
        self._rr = (order[0] + 1) % n
        return order

    def submit(self, root: int, *, block: bool = True,
               timeout: float | None = None, deadline: float | None = None,
               priority: int = 0) -> BFSFuture:
        """Enqueue one query on the least-backlogged admissible worker.

        Non-blocking submits fail over: if the chosen worker's queue is
        full the next-least-loaded one is tried, and ``QueueFull`` only
        propagates when EVERY worker is at capacity.  Blocking submits
        wait on the least-loaded worker (its thread is draining it).

        Raises ``Overloaded`` when every worker is evicted (after one
        inline re-admission probe), or — with ``shed=True`` and a
        ``deadline`` — when even the best worker's estimated queue delay
        already exceeds the deadline.
        """
        order = self._ranked()
        if not order:
            # all evicted: one inline probe is the last resort before
            # refusing (the background probe may simply not have run yet)
            self.probe_evicted()
            order = self._ranked()
            if not order:
                raise Overloaded(
                    f"all {len(self.workers)} workers evicted")
        if self.shed and deadline is not None:
            est = min(self.workers[i].estimated_delay() for i in order)
            if est > deadline:
                with self._lock:
                    self._n_shed += 1
                raise Overloaded(
                    f"estimated queue delay {est:.4f}s on the best of "
                    f"{len(order)} workers exceeds the request deadline "
                    f"{deadline:.4f}s")
        if block:
            return self.workers[order[0]].submit(
                root, block=True, timeout=timeout, deadline=deadline,
                priority=priority)
        last: QueueFull | None = None
        for i in order:
            try:
                return self.workers[i].submit(
                    root, block=False, deadline=deadline,
                    priority=priority)
            except QueueFull as exc:
                last = exc
        raise QueueFull(
            f"all {len(order)} admissible worker queues full") from last

    def backlog(self) -> int:
        return sum(w.backlog() for w in self.workers)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc == (None, None, None))

    # -- scheduler (fake-clock mode) --------------------------------------

    def pump(self, force: bool = False) -> list[WaveStats]:
        """Dispatch at most one due wave PER WORKER (fake-clock mode)."""
        out = []
        for w in list(self.workers):
            ws = w.pump(force)
            if ws is not None:
                out.append(ws)
        return out

    def flush(self) -> list[WaveStats]:
        """Dispatch everything pending on every worker, deadlines
        ignored.  Loops until the pool quiesces: an eviction mid-flush
        redispatches futures onto workers already flushed this pass, so
        one sweep is not enough."""
        out: list[WaveStats] = []
        while True:
            waves = [ws for w in list(self.workers) for ws in w.flush()]
            if not waves:
                return out
            out.extend(waves)

    def close(self, drain: bool = True, timeout: float | None = None):
        """Close every worker (serially; each drains its own queue).

        The pool is marked closed FIRST so in-flight failure handlers
        stop redispatching — a future must never be requeued onto a
        worker that is about to close underneath it (it would hang or die
        with a confusing ``BatcherClosed`` instead of its real error).
        Evicted workers are closed without drain: their queues were
        already moved to survivors at eviction, and asking a dead engine
        to serve a farewell wave helps nobody.
        """
        with self._lock:
            self._closed = True
            health = list(self._health)
        self._probe_stop.set()
        if self._probe_thread is not None:
            self._probe_thread.join(timeout)
            self._probe_thread = None
        for i, w in enumerate(self.workers):
            w.close(drain=drain and health[i] != EVICTED, timeout=timeout)

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict:
        """Pool-wide aggregate: exact totals summed across workers,
        latency percentiles over the POOLED per-wave latencies (so one
        slow worker shows up in the pool's p99, not just its own), plus
        each worker's own stats under ``per_worker`` and the health /
        eviction / shedding counters of the resilience layer.
        """
        per = [w.stats() for w in self.workers]
        lats: list[float] = []
        for w in self.workers:
            with w._cond:
                lats.extend(l for wave in w.waves for l in wave.latencies)
        with self._lock:
            self._refresh_health_locked()
            health = list(self._health)
            n_evict, n_redisp = self._n_evictions, self._n_redispatches
            n_shed = self._n_shed
            n_probe, n_probe_fail = self._n_probes, self._n_probe_failures
        out = dict(
            workers=len(self.workers),
            waves=sum(p["waves"] for p in per),
            errors=sum(p["errors"] for p in per),
            requests=sum(p["requests"] for p in per),
            busy_seconds=round(sum(p["busy_seconds"] for p in per), 4),
            engine_idle_seconds=round(
                sum(p["engine_idle_seconds"] for p in per), 4),
            pipeline=any(p["pipeline"] for p in per),
            health=health,
        )
        if n_evict or n_redisp:
            out.update(evictions=n_evict, redispatches=n_redisp)
        n_shed += sum(p.get("shed", 0) for p in per)
        if self.shed or n_shed:
            out["shed"] = n_shed
        if n_probe:
            out.update(probes=n_probe, probe_failures=n_probe_fail)
        n_failed = sum(p.get("requests_failed", 0) for p in per)
        if n_failed:
            out["requests_failed"] = n_failed
        n_slo = sum(p.get("slo_requests", 0) for p in per)
        if n_slo:
            n_miss = sum(p.get("slo_misses", 0) for p in per)
            out.update(slo_requests=n_slo, slo_misses=n_miss,
                       slo_miss_rate=round(n_miss / n_slo, 4))
        if any("traversed_edges" in p for p in per):
            trav = sum(p.get("traversed_edges", 0) for p in per)
            busy = sum(p["busy_seconds"] for p in per)
            # engine-busy TEPS: edges per second of ENGINE time summed
            # across workers — wall-clock delivered throughput is the
            # harness's job (it knows the stream's makespan, we don't)
            out.update(traversed_edges=int(trav),
                       aggregate_teps=round(trav / max(busy, 1e-12), 1))
        if lats:
            a = np.asarray(lats, np.float64)
            out.update(
                latency_mean=round(float(a.mean()), 4),
                latency_p50=round(float(np.percentile(a, 50)), 4),
                latency_p99=round(float(np.percentile(a, 99)), 4),
                latency_p999=round(float(np.percentile(a, 99.9)), 4),
            )
        if any("fault_tolerance" in p for p in per):
            out["fault_tolerance"] = [p.get("fault_tolerance")
                                      for p in per]
        out["per_worker"] = per
        return out
