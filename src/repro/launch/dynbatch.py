"""Asynchronous dynamic-batching driver for MS-BFS query serving.

ScalaBFS earns its throughput by keeping all 32 HBM pseudo-channels busy
with concurrent work; the software analogue is the MS-BFS engine, where one
traversal of the device-resident graph answers a whole batch of queries
(one bit-plane per source).  That engine only helps if queries actually
arrive batched — a stream of independent single-root requests gets none of
the ~21x batch-32 win.  This module closes that gap (the ROADMAP's
"dynamic batching for ``bfs_batch`` serving" item):

* ``DynamicBatcher.submit(root) -> BFSFuture`` enqueues one query and
  returns immediately.
* A wave scheduler coalesces every request that arrived within a
  configurable ``window`` (or up to ``max_batch``, default 32 — one full
  uint32 plane word) into a SINGLE MS-BFS wave: the roots are packed into
  plane slots (padded to a whole word so jitted step shapes stay constant,
  see ``bitmap.pad_plane_slots``), dispatched through ``run``/``run_batch``,
  and each future resolves with its own level vector, its queue latency,
  and the wave's aggregate-TEPS stats.
* Time is injected (``clock=``): with the default ``time.monotonic`` a
  daemon worker thread drives waves; with a fake clock the scheduler is a
  deterministic, single-threaded state machine driven by ``pump()`` /
  ``flush()`` — what the tests use.
* Backpressure: the request queue is bounded (``max_pending``); ``submit``
  blocks (threaded mode) or raises ``QueueFull``.  ``close(drain=True)``
  flushes every pending request into final waves before shutting down.
* Fault tolerance: hand the batcher an ``repro.ft.EngineSupervisor``
  (wrapping the real engine) and the worker loop delegates its WHOLE
  failure policy to it — watchdog deadlines, typed retry with backoff,
  quarantine bisection of poisoned roots, and the kernel degradation
  ladder.  Every future then resolves with either its levels or a typed
  error from the ``repro.ft`` taxonomy (``WaveTimeout`` /
  ``WaveAbandoned`` / ``RequestQuarantined``); nothing hangs and nothing
  retries unboundedly.  Without a supervisor the legacy policy applies:
  a deterministic (input-shaped) dispatch error isolates per-request with
  a hard cap of ONE singleton retry per request, and transient errors
  fail the wave's futures immediately.

Works in front of both engines returned by ``launch.serve.build_bfs_engine``:
the local ``MultiSourceBFSRunner`` and the sharded ``DistributedBFS``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro.core import (bitmap, count_traversed_edges, engine_num_vertices,
                        validate_roots)
from repro.ft.supervisor import (DETERMINISTIC, EngineSupervisor,
                                 classify_fault)


class QueueFull(RuntimeError):
    """Bounded request queue at capacity (backpressure signal)."""


class BatcherClosed(RuntimeError):
    """submit() after close() began, or result() of a cancelled request."""


@dataclasses.dataclass
class WaveStats:
    """One dispatched MS-BFS wave (shared by every future it resolved)."""

    wave_id: int
    batch: int                  # real requests served
    n_slots: int                # plane slots actually run (padded)
    t_start: float              # injected-clock time the wave was cut
    seconds: float              # service time (wall clock, traversal only)
    iterations: int
    edges_inspected: int
    push_iters: int
    pull_iters: int
    traversed_edges: int | None  # paper §VI-A metric over the REAL requests
    latencies: list[float] = dataclasses.field(default_factory=list)
    error: str | None = None    # set when the WHOLE wave failed
    # fault-tolerance accounting (supervised waves; zero on the legacy path)
    failed: int = 0             # requests resolved with a typed error
    traversals: int = 0         # engine calls incl. retries + bisection
    retries: int = 0
    timeouts: int = 0
    quarantined: list[int] = dataclasses.field(default_factory=list)
    demotions: list[str] = dataclasses.field(default_factory=list)

    @property
    def aggregate_teps(self) -> float | None:
        if self.traversed_edges is None:
            return None
        return self.traversed_edges / max(self.seconds, 1e-12)


class BFSFuture:
    """Handle for one submitted query; resolves when its wave completes."""

    def __init__(self, root: int, t_submit: float):
        self.root = int(root)
        self.t_submit = float(t_submit)
        self.wave: WaveStats | None = None
        self.latency: float | None = None   # injected-clock submit->resolve
        self._event = threading.Event()
        self._levels = None
        self._exc: BaseException | None = None

    def done(self) -> bool:
        """True once the future resolved — with levels OR a typed error.
        Poll with :meth:`exception` to see which without raising."""
        return self._event.is_set()

    def exception(self, timeout: float | None = 0) -> BaseException | None:
        """The typed error this request resolved with, without raising.

        Returns None while the request is still pending (disambiguate with
        :meth:`done`) or when it succeeded.  ``timeout`` bounds how long to
        wait for resolution (default 0: pure poll).
        """
        self._event.wait(timeout)
        return self._exc

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Level vector int64-compatible [|V|] for this root's traversal.

        A future whose wave was abandoned/quarantined raises its typed
        error (``repro.ft`` taxonomy) as soon as the wave resolves it —
        never blocking out the full ``timeout``.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"BFS query for root {self.root} not served in {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._levels

    def _resolve(self, levels, wave: WaveStats, latency: float):
        self._levels = levels
        self.wave = wave
        self.latency = latency
        self._event.set()

    def _fail(self, exc: BaseException):
        self._exc = exc
        self._event.set()


class DynamicBatcher:
    """Coalesce single-root BFS queries into MS-BFS waves.

    Wave-cut rule: a wave dispatches as soon as ``max_batch`` requests are
    pending, or when the OLDEST pending request has waited ``window``
    seconds, whichever comes first — so an idle stream pays at most one
    window of queueing delay and a hot stream always runs full plane words.

    ``clock=None`` (default) runs a daemon worker thread on real time.
    Passing a callable clock disables the thread: the scheduler becomes a
    deterministic state machine — advance the fake clock yourself and call
    :meth:`pump` (one due wave) or :meth:`flush` (everything, deadlines
    ignored).  ``start`` overrides the thread choice explicitly.
    """

    def __init__(self, engine, *, out_deg: np.ndarray | None = None,
                 window: float = 0.02, max_batch: int = 32,
                 max_pending: int = 1024, clock=None,
                 pad_to_plane: bool = True, start: bool | None = None,
                 stats_history: int = 4096):
        if max_batch < 1 or max_pending < 1 or window < 0:
            raise ValueError("need max_batch >= 1, max_pending >= 1, "
                             "window >= 0")
        self.engine = engine
        # an EngineSupervisor engine moves the whole failure policy (typed
        # retries, watchdog, bisection, degradation) out of this worker
        # loop: _dispatch delegates to supervisor.run_wave per-request
        self.supervisor = engine if isinstance(engine, EngineSupervisor) \
            else None
        self.window = float(window)
        self.max_batch = int(max_batch)
        self.max_pending = int(max_pending)
        self.pad_to_plane = bool(pad_to_plane)
        # BFSEngine protocol: every engine exposes num_vertices, out_deg
        # and run_batch (engine_num_vertices keeps a .g/.pg fallback for
        # older wrappers; engines without out_deg just lose TEPS stats)
        self.num_vertices = engine_num_vertices(engine)
        if out_deg is None:
            out_deg = getattr(engine, "out_deg", None)
        self.out_deg = None if out_deg is None else np.asarray(out_deg)
        self.clock = time.monotonic if clock is None else clock
        # waves history is bounded: a long-running server must not grow
        # without limit.  Percentiles cover the retained window; the
        # counters below keep the totals exact forever.
        self.waves: deque[WaveStats] = deque(maxlen=stats_history)
        self._n_waves = self._n_errors = 0
        self._n_requests = 0              # requests in error-free waves
        self._n_failed = 0                # requests resolved w/ typed error
        self._busy_seconds = 0.0
        self._traversed = 0
        self._pending: deque[BFSFuture] = deque()
        self._cond = threading.Condition()
        self._closed = False
        self._thread: threading.Thread | None = None
        threaded = (clock is None) if start is None else bool(start)
        if threaded:
            self._thread = threading.Thread(
                target=self._worker, name="dynbatch-worker", daemon=True)
            self._thread.start()

    # -- client side ------------------------------------------------------

    def submit(self, root: int, *, block: bool = True,
               timeout: float | None = None) -> BFSFuture:
        """Enqueue one BFS query; returns a :class:`BFSFuture`.

        Raises ``ValueError`` for an out-of-range root, ``QueueFull`` when
        the bounded queue stays at capacity (immediately if ``block=False``
        or no worker thread runs to drain it), ``BatcherClosed`` after
        :meth:`close`.
        """
        if not isinstance(root, (int, np.integer)):
            # reject rather than truncate, matching validate_roots
            raise ValueError(
                f"root must be an integer, got {type(root).__name__}")
        root = int(root)
        if self.num_vertices is not None:
            validate_roots(np.asarray([root]), self.num_vertices)
        with self._cond:
            if self._closed:
                raise BatcherClosed("submit() on a closed DynamicBatcher")
            # backpressure: blocking waits only help when a worker thread
            # is draining the queue concurrently
            can_wait = block and self._thread is not None
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while len(self._pending) >= self.max_pending:
                if not can_wait:
                    raise QueueFull(
                        f"{len(self._pending)} requests pending "
                        f"(max_pending={self.max_pending})")
                wait = (None if deadline is None
                        else deadline - time.monotonic())
                if wait is not None and wait <= 0:
                    raise QueueFull(
                        f"queue still full after {timeout}s")
                if not self._cond.wait(wait):
                    raise QueueFull(f"queue still full after {timeout}s")
                if self._closed:
                    raise BatcherClosed(
                        "submit() on a closed DynamicBatcher")
            fut = BFSFuture(root, self.clock())
            self._pending.append(fut)
            self._cond.notify_all()
        return fut

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc == (None, None, None))

    # -- scheduler --------------------------------------------------------

    def _deadline_locked(self) -> float | None:
        if not self._pending:
            return None
        return self._pending[0].t_submit + self.window

    def _cut_wave_locked(self) -> list[BFSFuture]:
        wave = [self._pending.popleft()
                for _ in range(min(self.max_batch, len(self._pending)))]
        self._cond.notify_all()        # free queue capacity
        return wave

    def pump(self, force: bool = False) -> WaveStats | None:
        """Dispatch at most one due wave (manual / fake-clock mode).

        A wave is due when ``max_batch`` requests are pending or the oldest
        has aged past ``window`` (``force=True`` ignores the deadline).
        Returns its :class:`WaveStats`, or None if nothing was due.
        """
        with self._cond:
            if not self._pending:
                return None
            due = (force or len(self._pending) >= self.max_batch
                   or self.clock() >= self._deadline_locked())
            if not due:
                return None
            wave = self._cut_wave_locked()
        return self._dispatch(wave)

    def flush(self) -> list[WaveStats]:
        """Dispatch ALL pending requests now, deadlines ignored."""
        out = []
        while True:
            w = self.pump(force=True)
            if w is None:
                return out
            out.append(w)

    def close(self, drain: bool = True, timeout: float | None = None):
        """Stop accepting requests; serve (``drain=True``) or cancel what
        is still queued.  Idempotent."""
        with self._cond:
            already = self._closed
            self._closed = True
            if not drain:
                cancelled = list(self._pending)
                self._pending.clear()
            self._cond.notify_all()
        if not drain:
            for f in cancelled:
                f._fail(BatcherClosed("request cancelled by close()"))
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():   # keep the handle: not drained
                raise TimeoutError(
                    f"worker still draining after {timeout}s")
            self._thread = None
        elif drain and not already:
            self.flush()

    def _worker(self):
        """Thread loop (real-clock mode): wait for the window deadline or a
        full wave, dispatch, repeat; drains the queue on close."""
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending:        # closed and drained
                    return
                now = self.clock()
                deadline = self._deadline_locked()
                if (len(self._pending) < self.max_batch
                        and not self._closed and now < deadline):
                    self._cond.wait(deadline - now)
                    continue
                wave = self._cut_wave_locked()
            self._dispatch(wave)

    # -- dispatch ---------------------------------------------------------

    def _dispatch(self, futures: list[BFSFuture]) -> WaveStats:
        if self.supervisor is not None:
            return self._dispatch_supervised(futures)
        roots = np.asarray([f.root for f in futures], np.int64)
        b = len(futures)
        slots = roots
        if self.pad_to_plane:
            slots, b = bitmap.pad_plane_slots(roots)
        ws = WaveStats(wave_id=self._n_waves, batch=b,
                       n_slots=int(slots.size), t_start=self.clock(),
                       seconds=0.0, iterations=0, edges_inspected=0,
                       push_iters=0, pull_iters=0, traversed_edges=None)
        t0 = time.perf_counter()
        try:
            # BFSEngine protocol: run_batch + last_stats, no engine sniffing
            levels = np.asarray(self.engine.run_batch(slots))
            ws.seconds = time.perf_counter() - t0
            st = dict(getattr(self.engine, "last_stats", {}))
            ws.iterations = int(st.get("iterations", 0))
            ws.edges_inspected = int(st.get("edges_inspected", 0))
            ws.push_iters = int(st.get("push_iters", 0))
            ws.pull_iters = int(st.get("pull_iters", 0))
            levels = bitmap.slice_plane_rows(levels, b)
            if self.out_deg is not None:
                # recount over the REAL requests only: pad slots are
                # duplicates and must not inflate the wave's TEPS
                ws.traversed_edges = count_traversed_edges(self.out_deg,
                                                           levels)
        except Exception as exc:       # resolve, don't kill the worker
            ws.seconds = time.perf_counter() - t0
            ws.error = f"{type(exc).__name__}: {exc}"
            self._record(ws)
            if classify_fault(exc) == DETERMINISTIC and len(futures) > 1:
                # a root rejected at dispatch time (possible when submit
                # had no |V| to validate against) must not fail its
                # co-batched neighbors: isolate each request as its own
                # singleton wave.  CAPPED: the len > 1 guard means a
                # failing singleton fails its future outright — no
                # request is ever retried more than once, and transient
                # faults never take this path (they fail the wave's
                # futures below; wrap the engine in an EngineSupervisor
                # for retry/backoff/bisection policy instead).
                for f in futures:
                    self._dispatch([f])
                return ws
            for f in futures:
                f._fail(exc)
            return ws
        # finish the wave record BEFORE waking any waiter: a client whose
        # result() just returned must see this wave in stats()
        t_res = self.clock()
        latencies = [t_res - f.t_submit for f in futures]
        ws.latencies.extend(latencies)
        self._record(ws)
        for f, lv, lat in zip(futures, levels, latencies):
            # copy the row: handing out a view would pin the whole padded
            # [B, |V|] wave matrix for as long as any client keeps it
            f._resolve(np.ascontiguousarray(lv), ws, lat)
        return ws

    def _dispatch_supervised(self, futures: list[BFSFuture]) -> WaveStats:
        """Delegate the wave's failure policy to the EngineSupervisor.

        ``run_wave`` never raises for engine faults: it returns one
        outcome per root (levels or typed error), after applying the
        watchdog / typed-retry / bisection / degradation policy.  This
        worker only books stats and resolves futures.
        """
        roots = np.asarray([f.root for f in futures], np.int64)
        b = len(futures)
        n_slots = (bitmap.num_words(b) * bitmap.WORD_BITS
                   if self.supervisor.pad_to_plane else b)
        ws = WaveStats(wave_id=self._n_waves, batch=b, n_slots=n_slots,
                       t_start=self.clock(), seconds=0.0, iterations=0,
                       edges_inspected=0, push_iters=0, pull_iters=0,
                       traversed_edges=None)
        try:
            wave = self.supervisor.run_wave(roots)
        except Exception as exc:  # defensive: run_wave absorbs engine faults
            ws.error = f"{type(exc).__name__}: {exc}"
            ws.failed = b
            self._record(ws)
            for f in futures:
                f._fail(exc)
            return ws
        # engine-busy seconds only (excludes retry backoff sleeps), so
        # aggregate TEPS over busy time stays comparable with the
        # unsupervised path
        ws.seconds = wave.seconds
        st = wave.stats
        ws.iterations = int(st.get("iterations", 0))
        ws.edges_inspected = int(st.get("edges_inspected", 0))
        ws.push_iters = int(st.get("push_iters", 0))
        ws.pull_iters = int(st.get("pull_iters", 0))
        ws.failed = wave.n_failed
        ws.traversals = wave.traversals
        ws.retries = wave.retries
        ws.timeouts = wave.timeouts
        ws.quarantined = list(wave.quarantined)
        ws.demotions = list(wave.demotions)
        if ws.failed == b:
            first = next(o.error for o in wave.outcomes
                         if o.error is not None)
            ws.error = f"{type(first).__name__}: {first}"
        ok_rows = [o.levels for o in wave.outcomes if o.ok]
        if self.out_deg is not None and ok_rows:
            ws.traversed_edges = count_traversed_edges(
                self.out_deg, np.stack(ok_rows))
        t_res = self.clock()
        for f in futures:
            ws.latencies.append(t_res - f.t_submit)
        self._record(ws)
        for f, o in zip(futures, wave.outcomes):
            if o.ok:
                f._resolve(o.levels, ws, t_res - f.t_submit)
            else:
                f.wave = ws
                f._fail(o.error)
        return ws

    def _record(self, ws: WaveStats):
        with self._cond:
            self.waves.append(ws)
            self._n_waves += 1
            self._n_failed += ws.failed
            if ws.error is not None:
                self._n_errors += 1
            else:
                self._n_requests += ws.batch - ws.failed
                self._busy_seconds += ws.seconds
                self._traversed += ws.traversed_edges or 0

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate serving stats: exact totals over the batcher's whole
        lifetime, latency percentiles over the last ``stats_history``
        waves retained in ``self.waves``."""
        with self._cond:               # consistent snapshot vs the worker
            waves = list(self.waves)
            n_waves, n_errors = self._n_waves, self._n_errors
            n_req, busy = self._n_requests, self._busy_seconds
            traversed = self._traversed
            n_failed = self._n_failed
        n_ok = n_waves - n_errors
        lats = np.asarray([l for w in waves if w.error is None
                           for l in w.latencies], np.float64)
        out = dict(
            waves=n_waves, errors=n_errors, requests=n_req,
            mean_batch=round(n_req / n_ok, 2) if n_ok else 0.0,
            busy_seconds=round(busy, 4),
        )
        if n_failed:
            out["requests_failed"] = n_failed
        if self.supervisor is not None:
            out["fault_tolerance"] = self.supervisor.stats()
        if self.out_deg is not None:   # without degrees TEPS is unknowable
            out.update(traversed_edges=int(traversed),
                       aggregate_teps=round(traversed / max(busy, 1e-12),
                                            1))
        if lats.size:
            out.update(
                latency_mean=round(float(lats.mean()), 4),
                latency_p50=round(float(np.percentile(lats, 50)), 4),
                latency_p99=round(float(np.percentile(lats, 99)), 4),
            )
        return out


def plane_wave_sizes(max_batch: int) -> list[int]:
    """Every padded wave size a batcher with cap ``max_batch`` can run.

    Partial waves pad to whole plane words (32, 64, ..., up to the padded
    cap); warm these shapes before serving so no wave pays jit compilation
    inside its measured service time.
    """
    padded = bitmap.num_words(max_batch) * bitmap.WORD_BITS
    return list(range(bitmap.WORD_BITS, padded + 1, bitmap.WORD_BITS))


def drive_open_loop(batcher: DynamicBatcher, roots, rate: float | None = None,
                    rng: np.random.Generator | None = None,
                    raise_errors: bool = True) -> list[BFSFuture]:
    """Submit ``roots`` open-loop, drain the batcher, return the futures.

    With ``rate`` (req/s) arrivals follow a Poisson process against an
    ABSOLUTE schedule — sleeping a fresh exponential gap per request would
    add the submit overhead on top of every gap and systematically
    undershoot the requested rate.  ``rate=None`` submits back-to-back.
    Raises the wave's error if any request failed; ``raise_errors=False``
    (the chaos arms) only asserts every future RESOLVED — with levels or a
    typed error — so injected faults don't abort the run but a hang still
    surfaces as ``TimeoutError``.
    """
    roots = np.asarray(roots)
    if rate:
        rng = rng or np.random.default_rng(0)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, roots.size))
    else:
        arrivals = np.zeros(roots.size)
    t0 = time.monotonic()
    futures = []
    for r, t_arr in zip(roots, arrivals):
        delay = t_arr - (time.monotonic() - t0)
        if delay > 0:
            time.sleep(delay)
        futures.append(batcher.submit(int(r)))
    batcher.close(drain=True)
    for f in futures:
        if raise_errors:
            f.result(timeout=0)    # drained => resolved; surface errors
        elif not f.done():         # resolution (either way) is mandatory
            raise TimeoutError(
                f"request for root {f.root} never resolved after drain")
    return futures
