"""Asynchronous dynamic-batching driver for MS-BFS query serving.

ScalaBFS earns its throughput by keeping all 32 HBM pseudo-channels busy
with concurrent work; the software analogue is the MS-BFS engine, where one
traversal of the device-resident graph answers a whole batch of queries
(one bit-plane per source).  That engine only helps if queries actually
arrive batched — a stream of independent single-root requests gets none of
the ~21x batch-32 win.  This module closes that gap (the ROADMAP's
"dynamic batching for ``bfs_batch`` serving" item):

* ``DynamicBatcher.submit(root) -> BFSFuture`` enqueues one query and
  returns immediately.  ``submit(root, deadline=, priority=)`` attaches an
  SLO: waves are cut urgency-first (priority tier, then oldest deadline)
  and a wave is cut EARLY when the tightest pending deadline is about to
  become unmeetable (``slo_margin``); per-wave SLO misses are accounted in
  :class:`WaveStats` and ``stats()``.
* A wave scheduler coalesces every request that arrived within a
  configurable ``window`` (or up to ``max_batch`` — any multiple of the
  32-bit plane word runs as a MULTI-WORD wave, e.g. ``max_batch=96`` is
  three plane words) into a SINGLE MS-BFS wave: the roots are packed into
  plane slots (padded to a whole word so jitted step shapes stay constant,
  see ``bitmap.pad_plane_slots``), dispatched through ``run``/``run_batch``,
  and each future resolves with its own level vector, its queue latency,
  and the wave's aggregate-TEPS stats.
* ``pipeline=True`` (threaded mode) splits dispatch into three stages —
  CUTTER (cut + validate + pad wave N+1 on host), DISPATCHER (the only
  stage that touches the engine), FINISHER (slice rows, resolve futures,
  book stats) — connected by bounded queues, so the engine never idles on
  host-side wave assembly or result bookkeeping under a saturating
  stream.  Engine idle between consecutive waves is measured and reported
  (``stats()["engine_idle_seconds"]``).
* Time is injected (``clock=``): with the default ``time.monotonic`` a
  daemon worker thread drives waves; with a fake clock the scheduler is a
  deterministic, single-threaded state machine driven by ``pump()`` /
  ``flush()`` — what the tests use.
* Backpressure: the request queue is bounded (``max_pending``); ``submit``
  blocks (threaded mode) or raises ``QueueFull``.  ``close(drain=True)``
  flushes every pending request into final waves before shutting down.
* Admission control (``shed=True``): a deadline request whose estimated
  queue delay (EWMA wave service x waves of backlog ahead) already
  exceeds its SLO is refused synchronously with a typed ``Overloaded`` —
  it fails in well under one wave time instead of burning engine time on
  a guaranteed miss and dragging every queued request later.
* Fault tolerance: hand the batcher an ``repro.ft.EngineSupervisor``
  (wrapping the real engine) and the worker loop delegates its WHOLE
  failure policy to it — watchdog deadlines, typed retry with backoff,
  quarantine bisection of poisoned roots, and the kernel degradation
  ladder.  Every future then resolves with either its levels or a typed
  error from the ``repro.ft`` taxonomy (``WaveTimeout`` /
  ``WaveAbandoned`` / ``RequestQuarantined``); nothing hangs and nothing
  retries unboundedly.  A wave carrying request deadlines passes the
  tightest remaining one to ``run_wave(deadline=)`` so the watchdog
  enforces the SLO during execution, not just at cut time.  Without a
  supervisor the legacy policy applies: a deterministic (input-shaped)
  dispatch error isolates per-request with a hard cap of ONE singleton
  retry per request, and transient errors fail the wave's futures
  immediately.

Works in front of both engines returned by ``launch.serve.build_bfs_engine``:
the local ``MultiSourceBFSRunner`` and the sharded ``DistributedBFS``.
For a pool of engines behind one submit surface see ``launch.pool``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque

import numpy as np

from repro.core import (bitmap, count_traversed_edges, engine_num_vertices,
                        validate_roots)
from repro.ft.supervisor import (DETERMINISTIC, EngineSupervisor,
                                 classify_fault)


class QueueFull(RuntimeError):
    """Bounded request queue at capacity (backpressure signal)."""


class BatcherClosed(RuntimeError):
    """submit() after close() began, or result() of a cancelled request."""


class Overloaded(RuntimeError):
    """Admission control shed this request: the estimated queue delay
    (EWMA wave service time x waves of backlog ahead) already exceeds the
    request's deadline, so serving it would burn engine time on a
    guaranteed SLO miss.  Raised synchronously by ``submit`` — a shed
    request fails in well under one wave service time, leaving the engine
    to the requests that can still make their deadlines."""


@dataclasses.dataclass
class WaveStats:
    """One dispatched MS-BFS wave (shared by every future it resolved)."""

    wave_id: int
    batch: int                  # real requests served
    n_slots: int                # plane slots actually run (padded)
    t_start: float              # injected-clock time the wave was cut
    seconds: float              # service time (wall clock, traversal only)
    iterations: int
    edges_inspected: int
    push_iters: int
    pull_iters: int
    traversed_edges: int | None  # paper §VI-A metric over the REAL requests
    latencies: list[float] = dataclasses.field(default_factory=list)
    error: str | None = None    # set when the WHOLE wave failed
    # SLO accounting (requests submitted with deadline=)
    deadline_requests: int = 0  # requests in this wave that carried an SLO
    slo_misses: int = 0         # of those: resolved late or with an error
    preempted: bool = False     # wave cut early to protect a deadline
    # fault-tolerance accounting (supervised waves; zero on the legacy path)
    failed: int = 0             # requests resolved with a typed error
    traversals: int = 0         # engine calls incl. retries + bisection
    retries: int = 0
    timeouts: int = 0
    quarantined: list[int] = dataclasses.field(default_factory=list)
    demotions: list[str] = dataclasses.field(default_factory=list)

    @property
    def aggregate_teps(self) -> float | None:
        if self.traversed_edges is None:
            return None
        return self.traversed_edges / max(self.seconds, 1e-12)


class BFSFuture:
    """Handle for one submitted query; resolves when its wave completes."""

    def __init__(self, root: int, t_submit: float,
                 t_deadline: float | None = None, priority: int = 0):
        self.root = int(root)
        self.t_submit = float(t_submit)
        # ABSOLUTE injected-clock deadline (t_submit + relative SLO)
        self.t_deadline = None if t_deadline is None else float(t_deadline)
        self.priority = int(priority)
        self.wave: WaveStats | None = None
        self.latency: float | None = None   # injected-clock submit->resolve
        self.slo_miss: bool | None = None   # None: no deadline was set
        self._seq = 0                       # submit order (stable sort key)
        self._event = threading.Event()
        self._levels = None
        self._exc: BaseException | None = None

    def done(self) -> bool:
        """True once the future resolved — with levels OR a typed error.
        Poll with :meth:`exception` to see which without raising."""
        return self._event.is_set()

    def exception(self, timeout: float | None = 0) -> BaseException | None:
        """The typed error this request resolved with, without raising.

        Returns None while the request is still pending (disambiguate with
        :meth:`done`) or when it succeeded.  ``timeout`` bounds how long to
        wait for resolution (default 0: pure poll).
        """
        self._event.wait(timeout)
        return self._exc

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Level vector int64-compatible [|V|] for this root's traversal.

        A future whose wave was abandoned/quarantined raises its typed
        error (``repro.ft`` taxonomy) as soon as the wave resolves it —
        never blocking out the full ``timeout``.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"BFS query for root {self.root} not served in {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._levels

    def _resolve(self, levels, wave: WaveStats, latency: float):
        self._levels = levels
        self.wave = wave
        self.latency = latency
        self._event.set()

    def _fail(self, exc: BaseException):
        self._exc = exc
        self._event.set()


@dataclasses.dataclass
class _Prepared:
    """Cutter-stage output: a cut wave, validated and padded on host.

    Everything the engine call needs, assembled BEFORE the engine is
    touched — under ``pipeline=True`` this happens while the previous
    wave is still traversing.
    """

    futures: list[BFSFuture]
    slots: np.ndarray           # padded plane slots handed to the engine
    b: int                      # real request count
    ws: WaveStats


@dataclasses.dataclass
class _Executed:
    """Dispatcher-stage output: one engine call's raw outcome."""

    prep: _Prepared
    levels: np.ndarray | None = None    # legacy path success
    wave: object | None = None          # SupervisedWave (supervised path)
    exc: BaseException | None = None
    # legacy deterministic isolate-retry: the parent wave's futures were
    # re-dispatched as singleton waves, which own their resolution — the
    # parent _Executed books its error wave but resolves nobody
    futures_owned_elsewhere: bool = False


class DynamicBatcher:
    """Coalesce single-root BFS queries into MS-BFS waves.

    Wave-cut rule: a wave dispatches as soon as ``max_batch`` requests are
    pending, when the OLDEST pending request has waited ``window`` seconds,
    or when the tightest pending deadline is within ``slo_margin`` of
    becoming unmeetable — whichever comes first.  An idle stream pays at
    most one window of queueing delay, a hot stream always runs full plane
    words, and an urgent request can preempt the window.

    ``max_batch`` may span several plane words (``W x 32``): the wave pads
    to whole words and the engine runs one multi-word traversal.

    ``clock=None`` (default) runs a daemon worker thread on real time.
    Passing a callable clock disables the thread: the scheduler becomes a
    deterministic state machine — advance the fake clock yourself and call
    :meth:`pump` (one due wave) or :meth:`flush` (everything, deadlines
    ignored).  ``start`` overrides the thread choice explicitly.

    ``pipeline=True`` (threaded mode only) runs the cutter / dispatcher /
    finisher stages on separate threads with bounded hand-off queues so
    host-side wave assembly and result bookkeeping overlap the engine's
    traversal instead of serializing with it.
    """

    def __init__(self, engine, *, out_deg: np.ndarray | None = None,
                 window: float = 0.02, max_batch: int = 32,
                 max_pending: int = 1024, clock=None,
                 pad_to_plane: bool = True, start: bool | None = None,
                 stats_history: int = 4096, pipeline: bool = False,
                 pipeline_depth: int = 2, slo_margin: float | None = None,
                 shed: bool = False, service_hint: float | None = None,
                 failure_handler=None):
        if max_batch < 1 or max_pending < 1 or window < 0:
            raise ValueError("need max_batch >= 1, max_pending >= 1, "
                             "window >= 0")
        if pipeline_depth < 1:
            raise ValueError("need pipeline_depth >= 1")
        if service_hint is not None and service_hint < 0:
            raise ValueError(f"service_hint must be >= 0, got {service_hint}")
        self.engine = engine
        # admission control: shed=True makes submit() raise Overloaded when
        # the estimated queue delay already exceeds the request's deadline.
        # service_hint primes the EWMA service estimate so the very first
        # waves aren't admitted blind (the estimate is 0 until a wave ran).
        self.shed = bool(shed)
        # pool hook: failure_handler(future, exc) -> bool runs for each
        # future about to FAIL with an engine-side error.  Returning True
        # hands ownership of the future to the handler (the pool
        # redispatches it to a surviving worker); this batcher then skips
        # its resolution and latency/SLO booking — the worker that finally
        # resolves it books the full submit->resolve latency.
        self.failure_handler = failure_handler
        # an EngineSupervisor engine moves the whole failure policy (typed
        # retries, watchdog, bisection, degradation) out of this worker
        # loop: _dispatch delegates to supervisor.run_wave per-request
        self.supervisor = engine if isinstance(engine, EngineSupervisor) \
            else None
        self.window = float(window)
        self.max_batch = int(max_batch)
        self.max_pending = int(max_pending)
        self.pad_to_plane = bool(pad_to_plane)
        # how long before an SLO deadline a wave must be cut for the
        # request to stand a chance; None tracks an EWMA of recent wave
        # service times measured on the injected clock (0 until a wave ran)
        self.slo_margin = None if slo_margin is None else float(slo_margin)
        # BFSEngine protocol: every engine exposes num_vertices, out_deg
        # and run_batch (engine_num_vertices keeps a .g/.pg fallback for
        # older wrappers; engines without out_deg just lose TEPS stats)
        self.num_vertices = engine_num_vertices(engine)
        if out_deg is None:
            out_deg = getattr(engine, "out_deg", None)
        self.out_deg = None if out_deg is None else np.asarray(out_deg)
        self.clock = time.monotonic if clock is None else clock
        # waves history is bounded: a long-running server must not grow
        # without limit.  Percentiles cover the retained window; the
        # counters below keep the totals exact forever.
        self.waves: deque[WaveStats] = deque(maxlen=stats_history)
        self._n_waves = self._n_errors = 0
        self._n_requests = 0              # requests in error-free waves
        self._n_failed = 0                # requests resolved w/ typed error
        self._n_slo_requests = 0          # lifetime requests with deadlines
        self._n_slo_misses = 0
        self._busy_seconds = 0.0          # engine-occupied (incl. failures)
        self._idle_seconds = 0.0          # engine gaps between waves
        self._last_exec_end: float | None = None
        # EWMA wave service (injected clock); primed by service_hint
        self._service_est = float(service_hint or 0.0)
        self._service_primed = service_hint is not None
        self._n_shed = 0                  # requests refused by admission
        # consecutive waves that failed for ENGINE reasons (quarantine-only
        # waves don't count: poisoned input, healthy engine).  The pool's
        # health state machine reads this to drive SUSPECT/EVICTED.
        self.consecutive_failures = 0
        self._traversed = 0
        self._inflight = 0                # cut but not yet finished
        self._seq = 0
        self._pending: deque[BFSFuture] = deque()
        self._n_slo_pending = 0           # pending with deadline/priority
        self._cond = threading.Condition()
        self._closed = False
        self._thread: threading.Thread | None = None
        self._dispatch_thread: threading.Thread | None = None
        self._finish_thread: threading.Thread | None = None
        threaded = (clock is None) if start is None else bool(start)
        self.pipeline = bool(pipeline)
        if self.pipeline and not threaded:
            raise ValueError(
                "pipeline=True needs the threaded worker (real clock or "
                "start=True); fake-clock pump()/flush() are synchronous")
        if self.pipeline:
            # bounded hand-off: the cutter preps at most pipeline_depth
            # waves ahead of the engine, the finisher queue is unbounded
            # (resolution must never stall the engine)
            self._dispatch_q: queue.Queue = queue.Queue(
                maxsize=int(pipeline_depth))
            self._finish_q: queue.Queue = queue.Queue()
            self._dispatch_thread = threading.Thread(
                target=self._pipeline_dispatcher, name="dynbatch-dispatch",
                daemon=True)
            self._finish_thread = threading.Thread(
                target=self._pipeline_finisher, name="dynbatch-finish",
                daemon=True)
            self._dispatch_thread.start()
            self._finish_thread.start()
        if threaded:
            self._thread = threading.Thread(
                target=self._worker, name="dynbatch-worker", daemon=True)
            self._thread.start()

    # -- client side ------------------------------------------------------

    def submit(self, root: int, *, block: bool = True,
               timeout: float | None = None, deadline: float | None = None,
               priority: int = 0) -> BFSFuture:
        """Enqueue one BFS query; returns a :class:`BFSFuture`.

        ``deadline`` is an SLO in RELATIVE seconds (injected clock): the
        request wants its result within that long of submission.  Waves
        are cut urgency-first and may be cut early to protect a deadline;
        whether each deadline was met is accounted per wave and in
        ``stats()`` (``slo_miss_rate``).  ``priority`` breaks ties before
        deadlines — lower runs first (default 0).

        Raises ``ValueError`` for an out-of-range root, ``QueueFull`` when
        the bounded queue stays at capacity (immediately if ``block=False``
        or no worker thread runs to drain it), ``BatcherClosed`` after
        :meth:`close`.
        """
        if not isinstance(root, (int, np.integer)):
            # reject rather than truncate, matching validate_roots
            raise ValueError(
                f"root must be an integer, got {type(root).__name__}")
        root = int(root)
        if self.num_vertices is not None:
            validate_roots(np.asarray([root]), self.num_vertices)
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be > 0 seconds, got {deadline}")
        with self._cond:
            if self._closed:
                raise BatcherClosed("submit() on a closed DynamicBatcher")
            if (self.shed and deadline is not None
                    and self._estimated_delay_locked() > deadline):
                self._n_shed += 1
                raise Overloaded(
                    f"estimated queue delay "
                    f"{self._estimated_delay_locked():.4f}s exceeds the "
                    f"request deadline {deadline:.4f}s "
                    f"(backlog={len(self._pending) + self._inflight}, "
                    f"service_est={self._service_est:.4f}s)")
            # backpressure: blocking waits only help when a worker thread
            # is draining the queue concurrently.  The timeout runs on the
            # INJECTED clock — a fake-clock batcher with start=True times
            # out when the fake clock passes the deadline, not wall time.
            can_wait = block and self._thread is not None
            t_quit = None if timeout is None else self.clock() + timeout
            while len(self._pending) >= self.max_pending:
                if not can_wait:
                    raise QueueFull(
                        f"{len(self._pending)} requests pending "
                        f"(max_pending={self.max_pending})")
                if t_quit is not None:
                    wait = t_quit - self.clock()
                    if wait <= 0:
                        raise QueueFull(f"queue still full after {timeout}s")
                    self._cond.wait(wait)
                else:
                    self._cond.wait()
                if self._closed:
                    raise BatcherClosed(
                        "submit() on a closed DynamicBatcher")
            t_sub = self.clock()
            fut = BFSFuture(root, t_sub,
                            None if deadline is None else t_sub + deadline,
                            priority)
            fut._seq = self._seq
            self._seq += 1
            self._pending.append(fut)
            if fut.t_deadline is not None or fut.priority != 0:
                self._n_slo_pending += 1
            self._cond.notify_all()
        return fut

    def _estimated_delay_locked(self) -> float:
        """Expected submit->resolve delay for a request admitted NOW:
        EWMA wave service time x (this wave + the waves of backlog queued
        ahead of it).  0 until a wave has run (or ``service_hint`` primed
        the estimate) — admission control never rejects blind."""
        backlog = len(self._pending) + self._inflight
        return self._service_est * (1.0 + backlog / self.max_batch)

    def estimated_delay(self) -> float:
        """Thread-safe :meth:`_estimated_delay_locked` (pool routing)."""
        with self._cond:
            return self._estimated_delay_locked()

    def _submit_future(self, fut: BFSFuture) -> None:
        """Enqueue an EXISTING future (pool redispatch after an eviction).

        Preserves the future's original ``t_submit`` / deadline / priority
        so its eventual latency and SLO verdict span the whole journey,
        not just the surviving worker's share.  Non-blocking: raises
        ``BatcherClosed`` / ``QueueFull`` so the caller can try the next
        worker instead of deadlocking inside a finisher thread.
        """
        with self._cond:
            if self._closed:
                raise BatcherClosed(
                    "redispatch onto a closed DynamicBatcher")
            if len(self._pending) >= self.max_pending:
                raise QueueFull(
                    f"{len(self._pending)} requests pending "
                    f"(max_pending={self.max_pending})")
            fut._seq = self._seq
            self._seq += 1
            self._pending.append(fut)
            if fut.t_deadline is not None or fut.priority != 0:
                self._n_slo_pending += 1
            self._cond.notify_all()

    def cancel_pending(self) -> list[BFSFuture]:
        """Pop every queued (not yet cut) request WITHOUT resolving it.

        Eviction support: the pool drains a failing worker's queue and
        redispatches the futures to survivors.  The caller owns the
        returned futures — anything it cannot place must be failed
        explicitly or clients hang.
        """
        with self._cond:
            out = list(self._pending)
            self._pending.clear()
            self._n_slo_pending = 0
            self._cond.notify_all()    # free queue capacity for waiters
        return out

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc == (None, None, None))

    # -- scheduler --------------------------------------------------------

    def _slo_margin_locked(self) -> float:
        return (self._service_est if self.slo_margin is None
                else self.slo_margin)

    def _deadline_locked(self) -> float | None:
        """Injected-clock time the next wave must be cut: the window of the
        oldest request, or earlier when a pending SLO deadline (minus the
        cut margin) preempts it."""
        if not self._pending:
            return None
        cut = self._pending[0].t_submit + self.window
        if self._n_slo_pending:
            margin = self._slo_margin_locked()
            for f in self._pending:
                if f.t_deadline is not None:
                    cut = min(cut, f.t_deadline - margin)
        return cut

    def _cut_wave_locked(self) -> list[BFSFuture]:
        """Pop the next wave: FIFO normally; urgency-first — (priority,
        oldest deadline, arrival) — when any pending request carries an
        SLO, so a late urgent request still makes the next wave."""
        k = min(self.max_batch, len(self._pending))
        if self._n_slo_pending == 0:
            wave = [self._pending.popleft() for _ in range(k)]
        else:
            ordered = sorted(
                self._pending,
                key=lambda f: (f.priority,
                               np.inf if f.t_deadline is None
                               else f.t_deadline, f._seq))
            wave = ordered[:k]
            taken = {id(f) for f in wave}
            self._pending = deque(
                f for f in self._pending if id(f) not in taken)
            self._n_slo_pending = sum(
                1 for f in self._pending
                if f.t_deadline is not None or f.priority != 0)
        self._inflight += len(wave)
        self._cond.notify_all()        # free queue capacity
        return wave

    def _try_cut_locked(self, force: bool = False
                        ) -> tuple[list[BFSFuture], bool] | None:
        """Cut the next wave if one is due; returns (futures, preempted)."""
        if not self._pending:
            return None
        full = len(self._pending) >= self.max_batch
        cut_at = self._deadline_locked()
        now = self.clock()
        if not (force or full or now >= cut_at):
            return None
        # preempted: cut before the window expired and before filling up,
        # purely to protect an SLO deadline
        preempted = (not force and not full
                     and now < self._pending[0].t_submit + self.window)
        return self._cut_wave_locked(), preempted

    def pump(self, force: bool = False) -> WaveStats | None:
        """Dispatch at most one due wave (manual / fake-clock mode).

        A wave is due when ``max_batch`` requests are pending, the oldest
        has aged past ``window``, or an SLO deadline preempts the window
        (``force=True`` ignores all deadlines).  Returns its
        :class:`WaveStats`, or None if nothing was due.
        """
        with self._cond:
            cut = self._try_cut_locked(force)
            if cut is None:
                return None
            wave, preempted = cut
        return self._dispatch(wave, preempted)

    def flush(self) -> list[WaveStats]:
        """Dispatch ALL pending requests now, deadlines ignored."""
        out = []
        while True:
            w = self.pump(force=True)
            if w is None:
                return out
            out.append(w)

    def close(self, drain: bool = True, timeout: float | None = None):
        """Stop accepting requests; serve (``drain=True``) or cancel what
        is still queued.  Idempotent."""
        with self._cond:
            already = self._closed
            self._closed = True
            if not drain:
                cancelled = list(self._pending)
                self._pending.clear()
                self._n_slo_pending = 0
            self._cond.notify_all()
        if not drain:
            for f in cancelled:
                f._fail(BatcherClosed("request cancelled by close()"))
        had_thread = self._thread is not None
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():   # keep the handle: not drained
                raise TimeoutError(
                    f"worker still draining after {timeout}s")
            self._thread = None
        if self._dispatch_thread is not None:
            # cutter is done: run the pipeline dry, in stage order
            self._dispatch_q.put(None)
            self._dispatch_thread.join(timeout)
            if self._dispatch_thread.is_alive():
                raise TimeoutError(
                    f"dispatcher still draining after {timeout}s")
            self._dispatch_thread = None
            self._finish_q.put(None)
            self._finish_thread.join(timeout)
            if self._finish_thread.is_alive():
                raise TimeoutError(
                    f"finisher still draining after {timeout}s")
            self._finish_thread = None
        elif drain and not already and not had_thread:
            self.flush()

    def backlog(self) -> int:
        """Queued + cut-but-unfinished requests (pool routing signal)."""
        with self._cond:
            return len(self._pending) + self._inflight

    def _worker(self):
        """Cutter loop (real-clock mode): wait for the window deadline, a
        full wave or an SLO preemption; cut; dispatch (or hand to the
        pipeline); repeat.  Drains the queue on close."""
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending:        # closed and drained
                    return
                cut = self._try_cut_locked(force=self._closed)
                if cut is None:
                    self._cond.wait(
                        max(self._deadline_locked() - self.clock(), 0.0))
                    continue
                wave, preempted = cut
            if self.pipeline:
                # prepare on THIS thread (cutter stage), then hand off;
                # put() blocks when pipeline_depth waves are already
                # prepped — natural backpressure on the cutter
                self._dispatch_q.put(self._prepare(wave, preempted))
            else:
                self._dispatch(wave, preempted)

    def _pipeline_dispatcher(self):
        """Dispatcher stage: the ONLY thread that touches the engine."""
        while True:
            prep = self._dispatch_q.get()
            if prep is None:
                return
            self._finish_q.put(self._execute(prep))

    def _pipeline_finisher(self):
        """Finisher stage: slice rows, resolve futures, book stats."""
        while True:
            ex = self._finish_q.get()
            if ex is None:
                return
            self._finish(ex)

    # -- dispatch stages --------------------------------------------------

    def _dispatch(self, futures: list[BFSFuture],
                  preempted: bool = False) -> WaveStats:
        """Synchronous dispatch: the three stages back-to-back (manual
        pump/flush mode and the non-pipelined worker)."""
        execs = self._execute(self._prepare(futures, preempted))
        return self._finish(execs)

    def _prepare(self, futures: list[BFSFuture],
                 preempted: bool = False) -> _Prepared:
        """Cutter stage: validate + pad the wave, before the engine."""
        roots = np.asarray([f.root for f in futures], np.int64)
        b = len(futures)
        if self.supervisor is not None:
            # the supervisor pads internally (it may bisect the wave)
            slots = roots
            n_slots = (bitmap.num_words(b) * bitmap.WORD_BITS
                       if self.supervisor.pad_to_plane else b)
        else:
            slots = roots
            if self.pad_to_plane:
                slots, b = bitmap.pad_plane_slots(roots)
            n_slots = int(slots.size)
        ws = WaveStats(wave_id=-1, batch=b, n_slots=n_slots,
                       t_start=self.clock(), seconds=0.0, iterations=0,
                       edges_inspected=0, push_iters=0, pull_iters=0,
                       traversed_edges=None, preempted=preempted)
        return _Prepared(futures=futures, slots=slots, b=b, ws=ws)

    def _wave_deadline(self, futures: list[BFSFuture]) -> float | None:
        """Tightest remaining request deadline, for the wave watchdog."""
        dls = [f.t_deadline for f in futures if f.t_deadline is not None]
        if not dls:
            return None
        return max(min(dls) - self.clock(), 1e-3)

    def _execute(self, prep: _Prepared) -> list[_Executed]:
        """Dispatcher stage: the engine call(s), nothing else.

        Engine-idle accounting rides here: the gap between the previous
        wave's engine return and this wave's engine entry is time the
        engine spent waiting on the host.
        """
        t0 = time.perf_counter()
        with self._cond:
            if self._last_exec_end is not None:
                self._idle_seconds += max(t0 - self._last_exec_end, 0.0)
        ws = prep.ws
        try:
            if self.supervisor is not None:
                wave = self.supervisor.run_wave(
                    prep.slots, deadline=self._wave_deadline(prep.futures))
                out = [_Executed(prep=prep, wave=wave)]
            else:
                # BFSEngine protocol: run_batch + last_stats, no sniffing
                levels = np.asarray(self.engine.run_batch(prep.slots))
                ws.seconds = time.perf_counter() - t0
                st = dict(getattr(self.engine, "last_stats", {}))
                ws.iterations = int(st.get("iterations", 0))
                ws.edges_inspected = int(st.get("edges_inspected", 0))
                ws.push_iters = int(st.get("push_iters", 0))
                ws.pull_iters = int(st.get("pull_iters", 0))
                tpp = st.get("traversed_per_plane")
                if tpp is not None:
                    # pad slots sliced off here, no host recount needed
                    ws.traversed_edges = int(
                        np.sum(np.asarray(tpp[: prep.b], np.int64)))
                out = [_Executed(prep=prep, levels=levels)]
        except Exception as exc:       # resolve, don't kill the worker
            ws.seconds = time.perf_counter() - t0
            out = [_Executed(prep=prep, exc=exc)]
            if (self.supervisor is None
                    and classify_fault(exc) == DETERMINISTIC
                    and len(prep.futures) > 1):
                # a root rejected at dispatch time (possible when submit
                # had no |V| to validate against) must not fail its
                # co-batched neighbors: isolate each request as its own
                # singleton wave.  CAPPED: the len > 1 guard means a
                # failing singleton fails its future outright — no
                # request is ever retried more than once, and transient
                # faults never take this path (they fail the wave's
                # futures below; wrap the engine in an EngineSupervisor
                # for retry/backoff/bisection policy instead).  The
                # singleton re-runs happen HERE, on the dispatcher
                # thread — they are engine calls.
                out[0].futures_owned_elsewhere = True
                for f in prep.futures:
                    out.extend(self._execute(self._prepare([f])))
        finally:
            with self._cond:
                self._last_exec_end = time.perf_counter()
        return out

    def _finish(self, execs: list[_Executed]) -> WaveStats:
        """Finisher stage: slice rows, resolve futures, book stats."""
        first: WaveStats | None = None
        for ex in execs:
            ws = self._finish_one(ex)
            if first is None:
                first = ws
        return first

    def _health_event(self, failed: bool):
        """One wave's verdict for the health state machine: engine-failure
        waves increment ``consecutive_failures``, healthy waves reset it."""
        with self._cond:
            self.consecutive_failures = (
                self.consecutive_failures + 1 if failed else 0)

    def _offer_failure(self, fut: BFSFuture, exc: BaseException) -> bool:
        """Ask the pool's failure handler to take over a failing future.
        A handler exception must not kill the finisher: treat it as
        'declined' and fail the future normally."""
        if self.failure_handler is None:
            return False
        try:
            return bool(self.failure_handler(fut, exc))
        except Exception:
            return False

    def _finish_one(self, ex: _Executed) -> WaveStats:
        prep, ws = ex.prep, ex.prep.ws
        futures = prep.futures
        if ex.wave is not None:
            return self._finish_supervised(ex)
        if ex.exc is not None:
            self._health_event(True)
            ws.error = f"{type(ex.exc).__name__}: {ex.exc}"
            if ex.futures_owned_elsewhere:
                # the singleton re-dispatches resolve (and account) the
                # futures; this record only books the failed parent wave
                self._record(ws)
                return ws
            kept = [f for f in futures
                    if not self._offer_failure(f, ex.exc)]
            # failed futures still resolved: their submit->fail latency
            # belongs in the percentile base (an SLO-blind p99 that
            # excludes precisely the slow failures is how misses hide).
            # Handed-off futures are NOT resolved here — their eventual
            # worker books them — but they left this worker's in-flight.
            t_res = self.clock()
            lats = [t_res - f.t_submit for f in kept]
            ws.latencies.extend(lats)
            ws.failed = len(kept)
            self._book_slo(ws, kept, t_res, all_failed=True)
            self._record(ws)
            for f, lat in zip(kept, lats):
                f.wave = ws
                f.latency = lat
                f.slo_miss = (None if f.t_deadline is None
                              else True)
                f._fail(ex.exc)
            self._dec_inflight(len(futures))
            return ws
        self._health_event(False)
        levels = bitmap.slice_plane_rows(ex.levels, prep.b)
        if ws.traversed_edges is None and self.out_deg is not None:
            # engines without per-plane counts: recount over the REAL
            # requests only — pad slots are duplicates and must not
            # inflate the wave's TEPS
            ws.traversed_edges = count_traversed_edges(self.out_deg,
                                                       levels)
        # finish the wave record BEFORE waking any waiter: a client whose
        # result() just returned must see this wave in stats()
        t_res = self.clock()
        latencies = [t_res - f.t_submit for f in futures]
        ws.latencies.extend(latencies)
        self._book_slo(ws, futures, t_res)
        self._record(ws)
        for f, lv, lat in zip(futures, levels, latencies):
            f.slo_miss = (None if f.t_deadline is None
                          else t_res > f.t_deadline)
            # copy the row: handing out a view would pin the whole padded
            # [B, |V|] wave matrix for as long as any client keeps it
            f._resolve(np.ascontiguousarray(lv), ws, lat)
        self._dec_inflight(len(futures))
        return ws

    def _finish_supervised(self, ex: _Executed) -> WaveStats:
        """Book a SupervisedWave: run_wave never raises for engine faults —
        it returns one outcome per root (levels or typed error) after the
        watchdog / typed-retry / bisection / degradation policy ran."""
        prep, ws, wave = ex.prep, ex.prep.ws, ex.wave
        futures = prep.futures
        # engine-busy seconds only (excludes retry backoff sleeps), so
        # aggregate TEPS over busy time stays comparable with the
        # unsupervised path
        ws.seconds = wave.seconds
        st = wave.stats
        ws.iterations = int(st.get("iterations", 0))
        ws.edges_inspected = int(st.get("edges_inspected", 0))
        ws.push_iters = int(st.get("push_iters", 0))
        ws.pull_iters = int(st.get("pull_iters", 0))
        ws.traversals = wave.traversals
        ws.retries = wave.retries
        ws.timeouts = wave.timeouts
        ws.quarantined = list(wave.quarantined)
        ws.demotions = list(wave.demotions)
        if wave.n_failed == len(futures):
            first = next(o.error for o in wave.outcomes
                         if o.error is not None)
            ws.error = f"{type(first).__name__}: {first}"
        # quarantine-only failures are poisoned INPUT, not a sick engine
        self._health_event(wave.n_failed > len(wave.quarantined))
        # offer each failing future to the pool before resolving: a
        # handed-off future is redispatched to a surviving worker and
        # books nothing here (the survivor resolves it end-to-end)
        handed = set()
        for f, o in zip(futures, wave.outcomes):
            if not o.ok and self._offer_failure(f, o.error):
                handed.add(id(f))
        ws.failed = wave.n_failed - len(handed)
        ok_rows = [o.levels for o in wave.outcomes if o.ok]
        if self.out_deg is not None and ok_rows:
            ws.traversed_edges = count_traversed_edges(
                self.out_deg, np.stack(ok_rows))
        t_res = self.clock()
        booked = [f for f in futures if id(f) not in handed]
        for f in booked:
            ws.latencies.append(t_res - f.t_submit)
        self._book_slo(ws, booked, t_res,
                       failed={id(futures[i]) for i, o in
                               enumerate(wave.outcomes) if not o.ok})
        self._record(ws)
        for f, o in zip(futures, wave.outcomes):
            if id(f) in handed:
                continue
            if f.t_deadline is not None:
                f.slo_miss = (not o.ok) or t_res > f.t_deadline
            if o.ok:
                f._resolve(o.levels, ws, t_res - f.t_submit)
            else:
                f.wave = ws
                f.latency = t_res - f.t_submit
                f._fail(o.error)
        self._dec_inflight(len(futures))
        return ws

    def _book_slo(self, ws: WaveStats, futures: list[BFSFuture],
                  t_res: float, all_failed: bool = False,
                  failed: set | None = None):
        """Per-wave SLO accounting: a deadline request misses when it
        resolves late OR resolves with an error (a typed failure inside
        the SLO window is still not the answer the client asked for)."""
        for f in futures:
            if f.t_deadline is None:
                continue
            ws.deadline_requests += 1
            if (all_failed or t_res > f.t_deadline
                    or (failed is not None and id(f) in failed)):
                ws.slo_misses += 1

    def _dec_inflight(self, n: int):
        with self._cond:
            self._inflight -= n

    def _record(self, ws: WaveStats):
        with self._cond:
            ws.wave_id = self._n_waves
            self.waves.append(ws)
            self._n_waves += 1
            self._n_failed += ws.failed
            self._n_slo_requests += ws.deadline_requests
            self._n_slo_misses += ws.slo_misses
            # a failed wave burned engine time too: busy seconds accrue
            # for every wave that ran, or lifetime TEPS reads inflated
            # under chaos
            self._busy_seconds += ws.seconds
            self._traversed += ws.traversed_edges or 0
            # injected-clock service estimate drives SLO preemption
            dt = max(self.clock() - ws.t_start, 0.0)
            if self._n_waves == 1 and not self._service_primed:
                self._service_est = dt
            else:
                self._service_est = 0.7 * self._service_est + 0.3 * dt
            if ws.error is not None:
                self._n_errors += 1
            else:
                self._n_requests += ws.batch - ws.failed

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate serving stats: exact totals over the batcher's whole
        lifetime, latency percentiles over the last ``stats_history``
        waves retained in ``self.waves``."""
        with self._cond:               # consistent snapshot vs the worker
            waves = list(self.waves)
            n_waves, n_errors = self._n_waves, self._n_errors
            n_req, busy = self._n_requests, self._busy_seconds
            idle = self._idle_seconds
            traversed = self._traversed
            n_failed = self._n_failed
            n_slo, n_miss = self._n_slo_requests, self._n_slo_misses
            n_shed = self._n_shed
            consec = self.consecutive_failures
        n_ok = n_waves - n_errors
        # EVERY resolved request contributes its latency — including the
        # ones whose wave failed: excluding them made p99 blind to
        # exactly the requests that blew the SLO
        lats = np.asarray([l for w in waves for l in w.latencies],
                          np.float64)
        out = dict(
            waves=n_waves, errors=n_errors, requests=n_req,
            mean_batch=round(n_req / n_ok, 2) if n_ok else 0.0,
            busy_seconds=round(busy, 4),
            engine_idle_seconds=round(idle, 4),
            pipeline=self.pipeline,
        )
        if n_failed:
            out["requests_failed"] = n_failed
        if self.shed or n_shed:
            out["shed"] = n_shed
        if consec:
            out["consecutive_failures"] = consec
        if n_slo:
            out.update(slo_requests=n_slo, slo_misses=n_miss,
                       slo_miss_rate=round(n_miss / n_slo, 4))
        if self.supervisor is not None:
            out["fault_tolerance"] = self.supervisor.stats()
        if self.out_deg is not None:   # without degrees TEPS is unknowable
            out.update(traversed_edges=int(traversed),
                       aggregate_teps=round(traversed / max(busy, 1e-12),
                                            1))
        if lats.size:
            out.update(
                latency_mean=round(float(lats.mean()), 4),
                latency_p50=round(float(np.percentile(lats, 50)), 4),
                latency_p99=round(float(np.percentile(lats, 99)), 4),
                latency_p999=round(float(np.percentile(lats, 99.9)), 4),
            )
        return out


def plane_wave_sizes(max_batch: int) -> list[int]:
    """Every padded wave size a batcher with cap ``max_batch`` can run.

    Partial waves pad to whole plane words (32, 64, ..., up to the padded
    cap); warm these shapes before serving so no wave pays jit compilation
    inside its measured service time.
    """
    padded = bitmap.num_words(max_batch) * bitmap.WORD_BITS
    return list(range(bitmap.WORD_BITS, padded + 1, bitmap.WORD_BITS))


def drive_open_loop(batcher, roots, rate: float | None = None,
                    rng: np.random.Generator | None = None,
                    raise_errors: bool = True,
                    deadline: float | None = None,
                    allow_shed: bool = False) -> list[BFSFuture]:
    """Submit ``roots`` open-loop, drain the batcher, return the futures.

    With ``rate`` (req/s) arrivals follow a Poisson process against an
    ABSOLUTE schedule — sleeping a fresh exponential gap per request would
    add the submit overhead on top of every gap and systematically
    undershoot the requested rate.  ``rate=None`` submits back-to-back.
    ``deadline`` attaches the same relative SLO to every request.
    Raises the wave's error if any request failed; ``raise_errors=False``
    (the chaos arms) only asserts every future RESOLVED — with levels or a
    typed error — so injected faults don't abort the run but a hang still
    surfaces as ``TimeoutError``.  ``allow_shed=True`` (serving with
    admission control on) treats a typed ``Overloaded`` reject as a
    normal open-loop outcome: the request is dropped, the stream keeps
    going, and only ADMITTED requests return futures.
    """
    roots = np.asarray(roots)
    if rate:
        rng = rng or np.random.default_rng(0)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, roots.size))
    else:
        arrivals = np.zeros(roots.size)
    t0 = time.monotonic()
    futures = []
    for r, t_arr in zip(roots, arrivals):
        delay = t_arr - (time.monotonic() - t0)
        if delay > 0:
            time.sleep(delay)
        try:
            futures.append(batcher.submit(int(r), deadline=deadline))
        except Overloaded:
            if not allow_shed:
                raise
    batcher.close(drain=True)
    for f in futures:
        if raise_errors:
            f.result(timeout=0)    # drained => resolved; surface errors
        elif not f.done():         # resolution (either way) is mandatory
            raise TimeoutError(
                f"request for root {f.root} never resolved after drain")
    return futures
