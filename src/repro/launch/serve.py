"""Batched serving drivers: LM decode, and batched BFS queries (MS-BFS).

LM path: prefill a batch of prompts, then decode tokens.  The decode loop
is the same jitted ``serve_step`` the dry-run lowers at 32k/500k KV
lengths; here it runs for real on the host devices with a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --batch 4 --prompt-len 16 --gen-tokens 24

BFS path: answer a batch of BFS queries over a device-resident graph with
one multi-source traversal (``bfs_batch``) — the serving analogue of the
paper's "keep every memory channel busy" aggregate-GTEPS metric.

  PYTHONPATH=src python -m repro.launch.serve --bfs-graph rmat16-16 \
      --bfs-batch 32

Async BFS path: stream SINGLE-root queries through the dynamic batcher
(``repro.launch.dynbatch``), which coalesces everything arriving within a
window into one MS-BFS wave and reports latency percentiles + aggregate
TEPS.

  PYTHONPATH=src python -m repro.launch.serve --bfs-graph rmat16-16 \
      --bfs-serve-async --bfs-requests 64 --bfs-window 0.05 --bfs-rate 200

Other vertex programs serve through the same batcher — ``--algo cc`` /
``--algo sssp`` run batched connected components / unit-weight SSSP waves
over the same plane-packed engine:

  PYTHONPATH=src python -m repro.launch.serve --algo cc --bfs-requests 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.launch.mesh import make_test_mesh
from repro.models.transformer import (init_decode_state, init_params,
                                      serve_step)
from repro.train.step import build_serve_step


def greedy_decode(arch: str, reduced: bool, batch: int, prompt_len: int,
                  gen_tokens: int, cache_len: int = 0, seed: int = 0) -> dict:
    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.key(seed))
    cache_len = cache_len or (prompt_len + gen_tokens)
    enc_len = max(prompt_len // 2, 8) if cfg.encoder_layers else 0
    caches = init_decode_state(cfg, batch, cache_len, enc_len=enc_len)
    abstract = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    fn, p_sh, c_sh = build_serve_step(
        cfg, mesh, abstract_params=abstract(params),
        abstract_caches=abstract(caches),
        abstract_tokens=jax.ShapeDtypeStruct((batch,), jnp.int32))
    params = jax.tree.map(jax.device_put, params, p_sh)
    caches = jax.tree.map(jax.device_put, caches, c_sh)

    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                          dtype=np.int32)
    # prefill = feeding prompt tokens through the decode path (tokenwise),
    # which exercises the same cache-update code the 32k cells lower.
    t0 = time.perf_counter()
    tok = jnp.asarray(prompt[:, 0])
    logits = None
    for pos in range(prompt_len):
        logits, caches = fn(params, caches, tok, jnp.int32(pos))
        tok = (jnp.asarray(prompt[:, pos + 1]) if pos + 1 < prompt_len
               else jnp.argmax(logits, -1).astype(jnp.int32))
    prefill_s = time.perf_counter() - t0

    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for pos in range(prompt_len, prompt_len + gen_tokens - 1):
        logits, caches = fn(params, caches, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_s = time.perf_counter() - t0
    gen = np.stack(out_tokens, 1)
    return {
        "arch": cfg.name, "batch": batch, "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "prefill_tok_s": round(batch * prompt_len / max(prefill_s, 1e-9), 1),
        "decode_tok_s": round(batch * (gen_tokens - 1) / max(decode_s, 1e-9),
                              1),
        "sample_output": gen[0][:12].tolist(),
        "finite": bool(np.isfinite(np.asarray(logits, np.float32)).all()),
    }


def build_engine(graph: str, *, algo: str = "bfs",
                 distributed: bool | None = None, pes_per_device: int = 2,
                 sparse_pull: bool = False):
    """Build a vertex-program query engine with the graph device-resident.

    ``algo``: "bfs" | "cc" | "sssp" (the shipped vertex programs — CC
    symmetrizes the graph first, components being an undirected notion).
    Returns (engine, out_degrees) where the degrees are those of the graph
    actually traversed (symmetrized for CC).  Single device -> the local
    runner for the program; multi-device -> ``DistributedBFS`` carrying
    the program (2 PEs per PC by default, the paper's Table II shape).
    The engine is meant to be built once and reused across ``bfs_batch``
    calls — the graph arrays stay device-resident between queries.

    ``sparse_pull=True`` enables the budgeted pull path on the local
    runners (tail pull levels expand only unvisited vertices' in-lists
    instead of scanning the whole CSC stream — the paper's actual pull
    semantics); the distributed engine ignores it for now.
    """
    from repro.core import (ConnectedComponentsRunner, MultiSourceBFSRunner,
                            SSSPRunner, build_local_graph, get_program,
                            partition_graph)
    from repro.graph import get_dataset, symmetrize_csr

    program = get_program(algo)
    ds = get_dataset(graph)
    csr, csc = ds.csr, ds.csc
    if program.undirected:
        csr = symmetrize_csr(csr)
        csc = csr            # a symmetrized graph is its own transpose
    deg = np.diff(csr.indptr)
    n_dev = jax.device_count()
    if distributed is None:
        distributed = n_dev > 1
    if distributed:
        from repro.compat import make_mesh
        from repro.core.bfs_distributed import DistributedBFS
        pg = partition_graph(csr, csc, n_dev * pes_per_device)
        mesh = make_mesh((n_dev,), ("data",))
        return DistributedBFS(pg, mesh, program=program), deg
    runner_cls = {"bfs": MultiSourceBFSRunner,
                  "cc": ConnectedComponentsRunner,
                  "sssp": SSSPRunner}[algo]
    return runner_cls(build_local_graph(csr, csc),
                      sparse_pull=sparse_pull), deg


def build_bfs_engine(graph: str, *, distributed: bool | None = None,
                     pes_per_device: int = 2):
    """BFS-only compat wrapper around :func:`build_engine`."""
    return build_engine(graph, algo="bfs", distributed=distributed,
                        pes_per_device=pes_per_device)


def bfs_batch(roots, *, graph: str = "rmat16-16", engine=None,
              out_deg=None, algo: str = "bfs") -> dict:
    """Serve a batch of vertex-program queries in one batched traversal.

    ``roots``: sequence of original vertex IDs, one query each.  Duplicate
    roots are allowed (each occupies its own plane slot and resolves
    independently); negative or >= |V| roots raise ``ValueError`` — they
    would otherwise scatter silently out of bounds (every engine enforces
    this via ``repro.core.validate_roots`` in its shared entry).  Pass a
    prebuilt ``engine`` (from :func:`build_engine`) to amortize graph
    residency across calls; otherwise one is built for ``graph``/``algo``.
    Returns value rows [B, |V|] (levels / hop distances) plus aggregate
    serving stats.
    """
    from repro.core import count_traversed_edges

    if engine is None:
        engine, out_deg = build_engine(graph, algo=algo)
    # no dtype cast here: the engine validates first (a float root must
    # raise, not truncate)
    roots = np.asarray(roots)
    t0 = time.perf_counter()
    # BFSEngine protocol: every engine answers run_batch and records
    # last_stats — no more sniffing for MultiSourceBFSRunner vs distributed
    levels = engine.run_batch(roots)
    seconds = time.perf_counter() - t0      # traversal only, not stats
    stats = dict(getattr(engine, "last_stats", {}))
    traversed = stats.pop("traversed_edges", None)
    if out_deg is not None:
        traversed = count_traversed_edges(out_deg, levels)
    stats.pop("seconds", None)
    stats["batch"] = int(roots.size)
    out = dict(levels=levels, seconds=round(seconds, 4), **stats)
    if traversed is not None:
        out["traversed_edges"] = traversed
        out["aggregate_teps"] = round(traversed / max(seconds, 1e-12), 1)
    return out


def serve_bfs(graph: str, batch: int, seed: int = 0,
              algo: str = "bfs") -> dict:
    engine, deg = build_engine(graph, algo=algo)
    rng = np.random.default_rng(seed)
    roots = rng.choice(np.flatnonzero(deg > 0), batch, replace=False)
    bfs_batch(roots, engine=engine, out_deg=deg)        # warm-up / compile
    out = bfs_batch(roots, engine=engine, out_deg=deg)
    levels = out.pop("levels")
    out.update(graph=graph, algo=algo,
               reached_mean=float((levels < (1 << 30)).sum(1).mean()))
    return out


def serve_bfs_async(graph: str, requests: int = 64, window: float = 0.05,
                    max_batch: int = 32, rate: float | None = None,
                    seed: int = 0, algo: str = "bfs",
                    workers: int = 1, pipeline: bool = False,
                    slo: float | None = None, sparse_pull: bool = False,
                    ft_max_retries: int | None = None,
                    ft_wave_deadline: float | None = None,
                    ft_chaos: float | None = None,
                    ft_integrity: str | None = None,
                    ft_audit_rate: float = 0.05,
                    pool_evict_after: int | None = None,
                    shed: bool = False) -> dict:
    """Serve a stream of single-root queries through the dynamic batcher.

    ``rate`` (req/s) spaces submissions with exponential inter-arrival
    sleeps (open-loop Poisson); ``rate=None`` submits as fast as possible.
    ``algo`` picks the vertex program — the batcher itself is
    engine-agnostic (the ``BFSEngine`` protocol), so CC and SSSP waves
    coalesce exactly like BFS waves.

    Production-serving knobs (ROADMAP item 3): ``max_batch`` may span
    multiple plane words (e.g. 96 = three words per wave);
    ``pipeline=True`` cuts/pads wave N+1 while wave N traverses;
    ``slo`` attaches that relative deadline (seconds) to every request
    so waves cut urgency-first and ``stats()`` reports the miss rate;
    ``workers > 1`` runs a :class:`~repro.launch.pool.WorkerPool` of
    engines (sharing one device-resident graph) behind one submit
    surface, each worker supervised independently when fault tolerance
    is on.

    Fault tolerance: ``ft_max_retries`` / ``ft_wave_deadline`` wrap the
    engine in an ``EngineSupervisor`` (typed retries, quarantine
    bisection, watchdog, degradation ladder); ``ft_chaos`` additionally
    interposes a ``FaultyEngine`` injecting faults at that per-wave rate
    so the policies can be watched firing against a live stream.  With a
    supervisor, the returned stats carry a ``fault_tolerance`` block and
    failed requests resolve with typed errors instead of raising here.

    Integrity & resilience: ``ft_integrity`` picks the answer-validation
    tier (``off`` | ``invariants`` | ``witness`` | ``audit``, see
    ``repro.ft.integrity``; implies supervision), ``ft_audit_rate`` the
    sampled fraction of clean waves the ``audit`` tier re-runs through
    the reference path.  ``pool_evict_after`` sets the worker pool's
    consecutive-failure eviction threshold (``workers > 1``); ``shed``
    turns on admission control — deadline requests whose estimated queue
    delay already exceeds their SLO are refused with a typed
    ``Overloaded`` instead of queued to miss.  The returned stats then
    carry an ``integrity`` block (checks / violations / audits / sheds /
    evictions) summed across workers.

    Returns the batcher's aggregate stats (waves, mean batch, latency
    p50/p99, aggregate TEPS over busy time) as a JSON-friendly dict.
    """
    from repro.launch.dynbatch import (DynamicBatcher, drive_open_loop,
                                       plane_wave_sizes)

    if workers < 1:
        raise ValueError(f"need workers >= 1, got {workers}")
    engine, deg = build_engine(graph, algo=algo, sparse_pull=sparse_pull)
    rng = np.random.default_rng(seed)
    roots = rng.choice(np.flatnonzero(deg > 0), requests, replace=True)
    for m in plane_wave_sizes(max_batch):      # warm-up / compile
        bfs_batch(np.resize(roots, m), engine=engine, out_deg=deg)
    # extra workers share the device-resident graph; jit caches are
    # module-level so the warm-up above covers every worker's shapes
    if workers > 1 and not hasattr(engine, "g"):
        raise ValueError("workers > 1 needs local runner engines "
                         "(DistributedBFS pools are a ROADMAP item)")
    engines = [engine] + [type(engine)(engine.g, sparse_pull=sparse_pull)
                          for _ in range(workers - 1)]
    supervised = (ft_max_retries is not None or ft_wave_deadline is not None
                  or ft_chaos is not None or ft_integrity is not None)
    if supervised:
        from repro.ft import (EngineSupervisor, FaultPlan, FaultyEngine,
                              IntegrityConfig)
        integrity = (None if ft_integrity is None else
                     IntegrityConfig(mode=ft_integrity,
                                     audit_rate=ft_audit_rate))
        wrapped = []
        for i, e in enumerate(engines):
            if ft_chaos:
                # rough horizon: every request could end up a singleton
                # wave; each worker draws an independent fault schedule
                plan = FaultPlan.random(max(2 * requests, 16), ft_chaos,
                                        seed=seed + i)
                e = FaultyEngine(e, plan)
            wrapped.append(EngineSupervisor(
                e,
                max_retries=2 if ft_max_retries is None else ft_max_retries,
                wave_deadline=ft_wave_deadline,
                integrity=integrity))
        engines = wrapped
    kw = dict(out_deg=deg, window=window, max_batch=max_batch,
              pipeline=pipeline, shed=shed)
    if len(engines) > 1:
        from repro.launch.pool import WorkerPool
        if pool_evict_after is not None:
            kw["evict_after"] = pool_evict_after
        batcher = WorkerPool(engines, **kw)
    else:
        batcher = DynamicBatcher(engines[0], **kw)
    try:
        drive_open_loop(batcher, roots, rate=rate, rng=rng,
                        raise_errors=not supervised, deadline=slo,
                        allow_shed=shed)
    finally:
        out = batcher.stats()
    out.update(graph=graph, algo=algo, requests=requests, window=window,
               max_batch=max_batch, rate=rate)
    if slo is not None:
        out["slo"] = slo
    if supervised or shed:
        out["integrity"] = _integrity_summary(out)
    return out


def _integrity_summary(stats: dict) -> dict:
    """One JSON-friendly resilience rollup: integrity detector counters
    summed across workers plus the pool's shedding/eviction totals."""
    ft = stats.get("fault_tolerance")
    blocks = (ft if isinstance(ft, list) else [ft]) if ft else []
    acc = dict(checks=0, violations=0, audits=0, audit_failures=0)
    mode = "off"
    for b in blocks:
        ig = (b or {}).get("integrity")
        if not ig:
            continue
        mode = ig.get("mode", mode)
        for k in acc:
            acc[k] += int(ig.get(k, 0))
    acc["mode"] = mode
    acc["sheds"] = int(stats.get("shed", 0))
    acc["evictions"] = int(stats.get("evictions", 0))
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--bfs-graph",
                    help="serve batched graph queries over this graph "
                         "instead of LM")
    ap.add_argument("--algo", choices=("bfs", "cc", "sssp"),
                    help="vertex program to serve (implies graph serving "
                         "through the dynamic batcher; default graph "
                         "small-12-8 when --bfs-graph is omitted)")
    ap.add_argument("--bfs-batch", type=int, default=32,
                    help="number of concurrent BFS queries")
    ap.add_argument("--bfs-serve-async", action="store_true",
                    help="serve single-root queries through the dynamic "
                         "batcher (launch.dynbatch) instead of one "
                         "pre-batched call")
    ap.add_argument("--bfs-window", type=float, default=0.05,
                    help="coalescing window in seconds (async serving)")
    ap.add_argument("--bfs-max-batch", type=int, default=32,
                    help="wave size cap = plane slots per MS-BFS wave")
    ap.add_argument("--bfs-requests", type=int, default=64,
                    help="number of single-root queries to stream (async)")
    ap.add_argument("--bfs-rate", type=float,
                    help="open-loop Poisson arrival rate in req/s "
                         "(default: submit as fast as possible)")
    ap.add_argument("--bfs-workers", type=int, default=1,
                    help="engine worker pool size (async serving; "
                         "engines share the device-resident graph)")
    ap.add_argument("--bfs-pipeline", action="store_true",
                    help="pipeline wave cutting against the engine "
                         "(cutter/dispatcher/finisher stages)")
    ap.add_argument("--bfs-slo", type=float,
                    help="attach this relative deadline (seconds) to "
                         "every request; waves cut urgency-first and "
                         "stats report the SLO miss rate")
    ap.add_argument("--bfs-sparse-pull", action="store_true",
                    help="budgeted sparse pull on tail levels (reads "
                         "only unvisited vertices' in-lists)")
    ap.add_argument("--ft-max-retries", type=int,
                    help="wrap the engine in an EngineSupervisor with this "
                         "transient-retry cap (async serving only)")
    ap.add_argument("--ft-wave-deadline", type=float,
                    help="fixed wave-watchdog deadline in seconds "
                         "(default: auto-calibrated from the running "
                         "median wave time); implies supervision")
    ap.add_argument("--ft-chaos", type=float,
                    help="inject faults at this per-wave rate through the "
                         "deterministic chaos engine (implies supervision)")
    ap.add_argument("--ft-integrity",
                    choices=("off", "invariants", "witness", "audit"),
                    help="traversal-integrity detector tier (implies "
                         "supervision): statvec invariants, sampled "
                         "witness audit, or rate-sampled differential "
                         "audit vs the reference path")
    ap.add_argument("--ft-audit-rate", type=float, default=0.05,
                    help="fraction of clean waves the audit tier re-runs "
                         "through the reference path (default 0.05)")
    ap.add_argument("--pool-evict-after", type=int,
                    help="evict a pool worker after this many consecutive "
                         "engine-failure waves (workers > 1; queued and "
                         "failing futures redispatch to survivors)")
    ap.add_argument("--shed", action="store_true",
                    help="admission control: refuse deadline requests "
                         "whose estimated queue delay already exceeds "
                         "their SLO (typed Overloaded, fails fast)")
    args = ap.parse_args()
    algo = args.algo or "bfs"
    if args.algo and not args.bfs_graph:
        args.bfs_graph = "small-12-8"
    # --algo routes through the dynamic batcher (engine-agnostic serving);
    # plain --bfs-graph keeps the one-pre-batched-call path
    if args.bfs_graph and (args.bfs_serve_async or args.algo):
        out = serve_bfs_async(args.bfs_graph, requests=args.bfs_requests,
                              window=args.bfs_window,
                              max_batch=args.bfs_max_batch,
                              rate=args.bfs_rate, algo=algo,
                              workers=args.bfs_workers,
                              pipeline=args.bfs_pipeline,
                              slo=args.bfs_slo,
                              sparse_pull=args.bfs_sparse_pull,
                              ft_max_retries=args.ft_max_retries,
                              ft_wave_deadline=args.ft_wave_deadline,
                              ft_chaos=args.ft_chaos,
                              ft_integrity=args.ft_integrity,
                              ft_audit_rate=args.ft_audit_rate,
                              pool_evict_after=args.pool_evict_after,
                              shed=args.shed)
    elif args.bfs_graph:
        out = serve_bfs(args.bfs_graph, args.bfs_batch)
    elif args.arch:
        out = greedy_decode(args.arch, args.reduced, args.batch,
                            args.prompt_len, args.gen_tokens)
    else:
        ap.error("one of --arch or --bfs-graph is required")
    print(json.dumps(out))


if __name__ == "__main__":
    main()
