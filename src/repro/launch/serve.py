"""Batched serving driver: prefill a batch of prompts, then decode tokens.

The decode loop is the same jitted ``serve_step`` the dry-run lowers at
32k/500k KV lengths; here it runs for real on the host devices with a
reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --batch 4 --prompt-len 16 --gen-tokens 24
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.launch.mesh import make_test_mesh
from repro.models.transformer import (init_decode_state, init_params,
                                      serve_step)
from repro.train.step import build_serve_step


def greedy_decode(arch: str, reduced: bool, batch: int, prompt_len: int,
                  gen_tokens: int, cache_len: int = 0, seed: int = 0) -> dict:
    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.key(seed))
    cache_len = cache_len or (prompt_len + gen_tokens)
    enc_len = max(prompt_len // 2, 8) if cfg.encoder_layers else 0
    caches = init_decode_state(cfg, batch, cache_len, enc_len=enc_len)
    abstract = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    fn, p_sh, c_sh = build_serve_step(
        cfg, mesh, abstract_params=abstract(params),
        abstract_caches=abstract(caches),
        abstract_tokens=jax.ShapeDtypeStruct((batch,), jnp.int32))
    params = jax.tree.map(jax.device_put, params, p_sh)
    caches = jax.tree.map(jax.device_put, caches, c_sh)

    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                          dtype=np.int32)
    # prefill = feeding prompt tokens through the decode path (tokenwise),
    # which exercises the same cache-update code the 32k cells lower.
    t0 = time.perf_counter()
    tok = jnp.asarray(prompt[:, 0])
    logits = None
    for pos in range(prompt_len):
        logits, caches = fn(params, caches, tok, jnp.int32(pos))
        tok = (jnp.asarray(prompt[:, pos + 1]) if pos + 1 < prompt_len
               else jnp.argmax(logits, -1).astype(jnp.int32))
    prefill_s = time.perf_counter() - t0

    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for pos in range(prompt_len, prompt_len + gen_tokens - 1):
        logits, caches = fn(params, caches, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_s = time.perf_counter() - t0
    gen = np.stack(out_tokens, 1)
    return {
        "arch": cfg.name, "batch": batch, "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "prefill_tok_s": round(batch * prompt_len / max(prefill_s, 1e-9), 1),
        "decode_tok_s": round(batch * (gen_tokens - 1) / max(decode_s, 1e-9),
                              1),
        "sample_output": gen[0][:12].tolist(),
        "finite": bool(np.isfinite(np.asarray(logits, np.float32)).all()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()
    out = greedy_decode(args.arch, args.reduced, args.batch,
                        args.prompt_len, args.gen_tokens)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
