import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step, in_shardings=..., out_shardings=...).lower(
**input_specs(arch)).compile()`` must succeed on the 16x16 single-pod mesh
(256 chips) AND the 2x16x16 multi-pod mesh (512 chips) for every cell, and
for the ScalaBFS engine itself (push + pull step programs at Q=256/512
graph shards).  The compiled artifact feeds §Roofline:

  * ``compiled.memory_analysis()``  -> bytes-per-device (proves it fits)
  * ``compiled.cost_analysis()``    -> XLA's own FLOPs/bytes (loop bodies
    counted ONCE - recorded for reference)
  * ``launch.hlo_analysis``         -> loop-aware FLOPs / HBM bytes /
    collective bytes parsed from the optimized HLO (what the roofline uses)

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --bfs rmat22-16 [--multi-pod] \
      [--dispatch bitmap|queue] [--crossbar staged|flat]
  python -m repro.launch.dryrun --all        # fan out every cell (resumable)

``--all`` runs each cell in a fresh subprocess (bounded memory, resumable:
cells with an existing JSON under --out are skipped).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


def _mesh_tag(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def _memory_summary(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": repr(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(m)
    return out


def _cost_summary(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": repr(e)}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    keep = {}
    for k, v in dict(c).items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds") or k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  keep_hlo: bool = False, microbatches: int = 8,
                  overrides: dict | None = None) -> dict:
    """Lower + compile one LM cell; returns the §Dry-run/§Roofline record."""
    import dataclasses

    import jax  # noqa: F401  (device count locked by XLA_FLAGS above)

    from repro.configs import get_config
    from repro.launch import hlo_analysis, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, cell_is_applicable, input_specs
    from repro.models.transformer import abstract_params
    from repro.train.step import (TrainConfig, abstract_train_state,
                                  build_prefill_step, build_serve_step,
                                  build_train_step)

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape_name]
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "kind": cell.kind, "overrides": overrides or {},
    }
    ok, why = cell_is_applicable(cfg, cell)
    if not ok:
        rec["skipped"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    specs = input_specs(cfg, shape_name)

    t0 = time.time()
    if cell.kind == "train":
        st = abstract_train_state(cfg)
        tcfg = TrainConfig(microbatches=microbatches)
        rec["microbatches"] = microbatches
        fn, _, _ = build_train_step(cfg, mesh, tcfg=tcfg, abstract_state=st,
                                    abstract_batch=specs["batch"])
        lowered = fn.lower(st, specs["batch"])
        tokens = cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        ap = abstract_params(cfg)
        fn, _, _ = build_prefill_step(cfg, mesh, abstract_params=ap,
                                      abstract_batch=specs["batch"])
        lowered = fn.lower(ap, specs["batch"])
        tokens = cell.global_batch * cell.seq_len
    else:  # decode
        ap = abstract_params(cfg)
        fn, _, _ = build_serve_step(cfg, mesh, abstract_params=ap,
                                    abstract_caches=specs["caches"],
                                    abstract_tokens=specs["tokens"])
        lowered = fn.lower(ap, specs["caches"], specs["tokens"],
                           specs["pos"])
        tokens = cell.global_batch
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    rec["memory_analysis"] = _memory_summary(compiled)
    rec["cost_analysis"] = _cost_summary(compiled)

    hlo = compiled.as_text()
    rec["hlo_lines"] = hlo.count("\n")
    per_dev = hlo_analysis.analyze_hlo_text(hlo)
    rec["per_device"] = per_dev
    rec["roofline"] = roofline.analyze_cell(
        per_dev, cell.kind, float(cfg.active_param_count()), float(tokens),
        n_dev)
    rec["n_devices"] = n_dev
    if keep_hlo:
        rec["hlo"] = hlo
    return rec


def lower_bfs_cell(graph_name: str, multi_pod: bool, dispatch: str,
                   crossbar: str, keep_hlo: bool = False) -> dict:
    """Lower + compile the BFS push and pull step programs."""
    import jax  # noqa: F401

    from repro.core.bfs_distributed import DistConfig, DistributedBFS
    from repro.graph.datasets import DATASETS
    from repro.launch import hlo_analysis, roofline
    from repro.launch.mesh import make_production_mesh

    meta = DATASETS[graph_name]
    n = 1 << meta.scale
    # symmetrization of undirected inputs doubles directed-edge count
    avg_deg = meta.edge_factor * (1 if meta.directed else 2)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = DistConfig(dispatch=dispatch, crossbar=crossbar)
    eng = DistributedBFS.abstract(mesh, n, cfg=cfg)
    sds = eng.abstract_inputs(avg_degree=avg_deg)
    budget = sds["indices"].shape[1]

    rec: dict = {
        "arch": f"scalabfs-{dispatch}-{crossbar}", "shape": graph_name,
        "mesh": _mesh_tag(multi_pod), "kind": "bfs",
        "num_vertices": n, "verts_per_shard": eng.vl, "shards": eng.q,
        "edge_budget": budget,
    }
    for phase, fn_name, args in (
        ("push", "push", (sds["frontier"], sds["visited"], sds["level"],
                          sds["lvl"], sds["indptr"], sds["indices"])),
        ("pull", "pull", (sds["frontier"], sds["visited"], sds["level"],
                          sds["lvl"], sds["indptr"], sds["indices"])),
    ):
        t0 = time.time()
        step = eng._get(fn_name, budget)
        lowered = step.lower(*args)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        per_dev = hlo_analysis.analyze_hlo_text(hlo)
        rec[phase] = {
            "compile_s": round(time.time() - t0, 2),
            "memory_analysis": _memory_summary(compiled),
            "cost_analysis": _cost_summary(compiled),
            "per_device": per_dev,
            "roofline": roofline.roofline_terms(per_dev),
            "hlo_lines": hlo.count("\n"),
        }
        if keep_hlo:
            rec[phase]["hlo"] = hlo
    return rec


# ---------------------------------------------------------------------------
# Fan-out driver (resumable; one subprocess per cell)
# ---------------------------------------------------------------------------

BFS_CELLS = [
    # (graph, dispatch, crossbar) - default engine on both meshes, plus the
    # dispatcher design space on the single pod for §Perf.
    ("rmat22-16", "bitmap", "staged"),
    ("rmat22-16", "bitmap", "flat"),
    ("rmat22-16", "queue", "staged"),
    ("rmat23-64", "bitmap", "staged"),
    ("lj-like", "bitmap", "staged"),
]


def all_cells(out_dir: str):
    from repro.configs import ARCH_NAMES
    from repro.launch.shapes import SHAPES
    cells = []
    for multi_pod in (False, True):
        tag = _mesh_tag(multi_pod)
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                path = os.path.join(out_dir, f"{arch}__{shape}__{tag}.json")
                args = ["--arch", arch, "--shape", shape]
                cells.append((path, args + (["--multi-pod"] if multi_pod
                                            else [])))
        for graph, dispatch, crossbar in BFS_CELLS:
            if multi_pod and (dispatch, crossbar) != ("bitmap", "staged"):
                continue  # design-space sweep is single-pod only
            name = f"bfs-{graph}-{dispatch}-{crossbar}"
            path = os.path.join(out_dir, f"{name}__{tag}.json")
            args = ["--bfs", graph, "--dispatch", dispatch,
                    "--crossbar", crossbar]
            cells.append((path, args + (["--multi-pod"] if multi_pod
                                        else [])))
    return cells


def run_all(out_dir: str, timeout: float = 3000.0) -> int:
    os.makedirs(out_dir, exist_ok=True)
    cells = all_cells(out_dir)
    failures = 0
    for i, (path, args) in enumerate(cells):
        if os.path.exists(path):
            print(f"[{i+1}/{len(cells)}] SKIP (done) {os.path.basename(path)}",
                  flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               *args, "--json-out", path]
        t0 = time.time()
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=timeout)
        except subprocess.TimeoutExpired:
            print(f"[{i+1}/{len(cells)}] TIMEOUT {os.path.basename(path)}",
                  flush=True)
            failures += 1
            continue
        dt = time.time() - t0
        if p.returncode != 0:
            failures += 1
            tail = (p.stderr or p.stdout).strip().splitlines()[-12:]
            print(f"[{i+1}/{len(cells)}] FAIL ({dt:.0f}s) "
                  f"{os.path.basename(path)}\n  " + "\n  ".join(tail),
                  flush=True)
        else:
            print(f"[{i+1}/{len(cells)}] ok ({dt:.0f}s) "
                  f"{os.path.basename(path)}", flush=True)
    print(f"done: {len(cells)} cells, {failures} failures", flush=True)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--bfs", metavar="GRAPH")
    ap.add_argument("--dispatch", default="bitmap",
                    choices=["bitmap", "queue"])
    ap.add_argument("--crossbar", default="staged",
                    choices=["staged", "flat"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--json-out")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--override", action="append", default=[],
                    help="ArchConfig field override, e.g. moe_dispatch=onehot")
    args = ap.parse_args()
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = int(v) if v.isdigit() else v

    if args.all:
        return 1 if run_all(args.out) else 0

    try:
        if args.bfs:
            rec = lower_bfs_cell(args.bfs, args.multi_pod, args.dispatch,
                                 args.crossbar, keep_hlo=args.keep_hlo)
        else:
            assert args.arch and args.shape, "--arch and --shape required"
            rec = lower_lm_cell(args.arch, args.shape, args.multi_pod,
                                keep_hlo=args.keep_hlo,
                                microbatches=args.microbatches,
                                overrides=overrides or None)
    except Exception:
        traceback.print_exc()
        return 1

    print(json.dumps(rec, indent=2, default=str))
    if args.json_out:
        os.makedirs(os.path.dirname(args.json_out) or ".", exist_ok=True)
        with open(args.json_out, "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
