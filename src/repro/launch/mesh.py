"""Production meshes.  Functions, not module constants, so importing this
module never touches jax device state."""
from __future__ import annotations

import jax

from repro.compat import make_mesh as _make


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_test_mesh(num_devices: int | None = None):
    """Small local mesh over however many (host) devices exist."""
    n = num_devices or len(jax.devices())
    if n == 1:
        return _make((1, 1, 1), ("pod", "data", "model"))
    # factor n into (pod, data, model) greedily
    pod = 2 if n % 2 == 0 and n > 4 else 1
    rem = n // pod
    model = 1
    for m in (4, 2):
        if rem % m == 0:
            model = m
            break
    data = rem // model
    return _make((pod, data, model), ("pod", "data", "model"))
