"""End-to-end training driver: data -> sharded step -> checkpoint/restart.

Runs on whatever devices exist (CPU here, a pod in production): the mesh,
shardings, data pipeline, optimizer, async checkpointing, failure
injection/retry and straggler flagging are the same code paths the
multi-pod dry-run lowers.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 40 --global-batch 8 --seq-len 128 --ckpt-every 10 \
      --inject-failures 17 --ckpt-dir /tmp/repro_ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import DataConfig, make_batch
from repro.ft.failures import FailureInjector, InjectedFailure, StepTimer
from repro.launch.mesh import make_test_mesh
from repro.models.config import ArchConfig
from repro.train.step import (TrainConfig, build_train_step,
                              init_train_state, state_shardings,
                              abstract_train_state)


@dataclasses.dataclass
class RunConfig:
    arch: str
    reduced: bool = True
    steps: int = 40
    global_batch: int = 8
    seq_len: int = 128
    microbatches: int = 1
    ckpt_dir: str = ""
    ckpt_every: int = 0
    inject_failures: tuple[int, ...] = ()
    seed: int = 0
    log_every: int = 1


def data_config(cfg: ArchConfig, run: RunConfig) -> DataConfig:
    kind = {"vision_stub": "embeds", "audio_stub": "frames"}.get(
        cfg.frontend, "tokens")
    return DataConfig(vocab_size=cfg.vocab_size,
                      global_batch=run.global_batch, seq_len=run.seq_len,
                      seed=run.seed, kind=kind, d_model=cfg.d_model,
                      enc_len=max(run.seq_len // 2, 8))


def train(run: RunConfig) -> dict:
    cfg = (get_reduced_config(run.arch) if run.reduced
           else get_config(run.arch))
    mesh = make_test_mesh()
    tcfg = TrainConfig(microbatches=run.microbatches)
    state = init_train_state(cfg, jax.random.key(run.seed))
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    st_sh = state_shardings(abstract, mesh)
    state = jax.tree.map(jax.device_put, state, st_sh)
    dcfg = data_config(cfg, run)
    step_fn = None     # built lazily so batch specs come from real batch

    saver = ckpt.AsyncCheckpointer(run.ckpt_dir) if run.ckpt_dir else None
    injector = FailureInjector(run.inject_failures)
    timer = StepTimer()
    log: list[dict] = []
    restarts = 0

    def build(batch):
        ab = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        fn, _, b_sh = build_train_step(cfg, mesh, tcfg=tcfg,
                                       abstract_state=abstract,
                                       abstract_batch=ab)
        return fn, b_sh

    step = 0
    while step < run.steps:
        try:
            injector.check(step)
            batch = make_batch(dcfg, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if step_fn is None:
                step_fn, b_sh = build(batch)
            batch = jax.tree.map(jax.device_put, batch, b_sh)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["total_loss"])
            dt = time.perf_counter() - t0
            straggler = timer.record(step, dt)
            if step % run.log_every == 0:
                rec = dict(step=step, loss=round(loss, 4),
                           grad_norm=round(float(metrics["grad_norm"]), 3),
                           sec=round(dt, 3), straggler=bool(straggler))
                log.append(rec)
                print(json.dumps(rec), flush=True)
            if saver and run.ckpt_every and (step + 1) % run.ckpt_every == 0:
                saver.save(step + 1, state)
            step += 1
        except InjectedFailure:
            restarts += 1
            print(f"[ft] injected failure at step {step}; restoring",
                  flush=True)
            if saver:
                saver.wait()
            last = ckpt.latest_step(run.ckpt_dir) if run.ckpt_dir else None
            if last is None:
                # no checkpoint yet: restart from scratch (deterministic data)
                state = init_train_state(cfg, jax.random.key(run.seed))
                state = jax.tree.map(jax.device_put, state, st_sh)
                step = 0
            else:
                state, _ = ckpt.restore(run.ckpt_dir, last, abstract, st_sh)
                step = last
    if saver:
        saver.wait()
    losses = [r["loss"] for r in log]
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "restarts": restarts, "straggler_flags": timer.flags,
            "steps": step, "log": log}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated step numbers")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    fails = tuple(int(x) for x in args.inject_failures.split(",") if x)
    run = RunConfig(arch=args.arch, reduced=args.reduced, steps=args.steps,
                    global_batch=args.global_batch, seq_len=args.seq_len,
                    microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every, inject_failures=fails,
                    seed=args.seed)
    out = train(run)
    print(json.dumps({k: v for k, v in out.items() if k != "log"}))


if __name__ == "__main__":
    main()
