"""Three-term roofline analysis from compiled dry-run artifacts.

Target hardware is TPU v5e (this container is CPU-only, so nothing is
timed): per chip 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link.
All inputs are *per-device* quantities (the HLO parser sees SPMD shard
shapes), so the three terms

    compute    = flops_per_device   / peak_flops
    memory     = bytes_per_device   / hbm_bw
    collective = coll_bytes_per_dev / ici_bw

are per-chip seconds for one step; they equal the global-quantity form
``HLO_FLOPs / (chips x peak)`` exactly.  The step's lower-bound time under
perfect overlap is ``max`` of the three; the dominant term is the
bottleneck the §Perf loop iterates on.

``MODEL_FLOPS`` is the useful-math floor: 6·N·D for a train step (fwd+bwd),
2·N·D for prefill, 2·N·B for one decode step (N = active params, D =
tokens).  ``useful_ratio = MODEL_FLOPS / HLO_FLOPs`` exposes remat /
redundancy waste; ``roofline_fraction = t_model / t_bound`` is the score:
the fraction of the perfect-overlap bound spent on useful math.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_bf16: float = 197e12     # FLOP/s per chip
    hbm_bw: float = 819e9         # B/s per chip
    ici_bw: float = 50e9          # B/s per link (we count one link's worth)


V5E = Hardware()


def roofline_terms(per_device: dict, hw: Hardware = V5E) -> dict:
    """per_device: {flops, bytes, collective_bytes} -> 3 terms (seconds)."""
    t_comp = per_device["flops"] / hw.peak_bf16
    t_mem = per_device["bytes"] / hw.hbm_bw
    t_coll = per_device.get("collective_bytes", 0.0) / hw.ici_bw
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    return dict(terms, dominant=dominant.removesuffix("_s"),
                bound_s=bound)


def model_flops(kind: str, active_params: float, tokens: float) -> float:
    """Useful-math floor for the cell.

    kind: train (6·N·D: fwd 2 + bwd 4) | prefill (2·N·D) | decode (2·N·B,
    tokens = batch since one token decodes per sequence)."""
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    return mult * active_params * tokens


def analyze_cell(per_device: dict, kind: str, active_params: float,
                 tokens: float, n_devices: int, hw: Hardware = V5E) -> dict:
    """Full §Roofline record for one (arch x shape x mesh) cell."""
    terms = roofline_terms(per_device, hw)
    mf_total = model_flops(kind, active_params, tokens)
    mf_dev = mf_total / n_devices
    hlo_flops = max(per_device["flops"], 1.0)
    t_model = mf_dev / hw.peak_bf16
    return dict(
        terms,
        model_flops_total=mf_total,
        model_flops_per_device=mf_dev,
        hlo_flops_per_device=per_device["flops"],
        useful_ratio=mf_dev / hlo_flops,
        roofline_fraction=t_model / max(terms["bound_s"], 1e-30),
    )


def format_row(name: str, rec: dict) -> str:
    return (f"{name:40s} comp={rec['compute_s']*1e3:9.3f}ms "
            f"mem={rec['memory_s']*1e3:9.3f}ms "
            f"coll={rec['collective_s']*1e3:9.3f}ms "
            f"dom={rec['dominant']:10s} "
            f"useful={rec['useful_ratio']:6.3f} "
            f"roofline={rec['roofline_fraction']*100:6.2f}%")
