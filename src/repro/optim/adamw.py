"""AdamW with global-norm clipping and cosine schedule (no external deps).

Optimizer state shards exactly like its parameters (ZeRO via GSPMD): m/v
inherit the param PartitionSpec at jit boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / scalar gains."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    return not any(s in leaf for s in
                   ("ln", "bias", "_b", "lam", "a_log", "d_skip", "dt_bias"))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
