"""Synthetic token pipeline: deterministic, host-sharded, restart-safe.

The generator is a pure function of (seed, step, host_slice) so that (a)
resuming from a checkpoint replays exactly the right batch, and (b) each
host in a multi-host job materializes only its slice of the global batch —
the standard input-pipeline contract at pod scale.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    kind: str = "tokens"      # tokens | embeds | frames
    d_model: int = 0          # for embeds/frames stubs
    enc_len: int = 0


def host_slice(cfg: DataConfig, process_index: int, process_count: int):
    per = cfg.global_batch // process_count
    return process_index * per, per


def make_batch(cfg: DataConfig, step: int, process_index: int = 0,
               process_count: int = 1) -> dict:
    start, per = host_slice(cfg, process_index, process_count)
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, start]))
    # Markov-ish synthetic tokens: next-token structure so loss can fall.
    base = rng.integers(0, cfg.vocab_size, size=(per, cfg.seq_len + 1),
                        dtype=np.int32)
    drift = np.cumsum(base % 7, axis=1).astype(np.int32) % cfg.vocab_size
    toks = (base + drift) % cfg.vocab_size
    batch = {"labels": toks[:, 1:]}
    if cfg.kind == "tokens":
        batch["tokens"] = toks[:, :-1]
    elif cfg.kind == "embeds":
        batch["embeds"] = rng.standard_normal(
            (per, cfg.seq_len, cfg.d_model)).astype(np.float32) * 0.02
    elif cfg.kind == "frames":
        batch["tokens"] = toks[:, :-1]
        batch["frames"] = rng.standard_normal(
            (per, cfg.enc_len, cfg.d_model)).astype(np.float32) * 0.02
    return batch
