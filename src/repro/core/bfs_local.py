"""Single-device BFS engine implementing the paper's Algorithm 2.

Three bitmaps (current frontier / next frontier / visited) + a level array.
Two execution paths:

* ``bfs_reference`` — fully-jit `lax.while_loop`, edge-parallel (dense) steps.
  This is the correctness oracle-adjacent path used by tests and by the
  distributed engine's per-shard step.
* ``BFSRunner`` — work-efficient gather path mirroring the hardware pipeline
  P1 (workload prep: frontier compaction), P2 (neighbor checking: CSR/CSC
  gather + bitmap tests), P3 (result writing: fused bitmap update).  It
  counts *inspected edges* per mode, which is what the paper's Fig. 8/10
  comparisons measure, and drives GTEPS benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap
from repro.core.scheduler import PULL, PUSH, SchedulerConfig, choose_mode
from repro.graph.csr import CSRGraph, edge_sources

INF = jnp.int32(2 ** 30)


@partial(jax.tree_util.register_dataclass,
         data_fields=("out_indptr", "out_indices", "in_indptr", "in_indices",
                      "out_src", "in_child"),
         meta_fields=("n", "n_pad"))
@dataclasses.dataclass(frozen=True)
class LocalGraph:
    """Device-resident graph arrays (vertex space padded to words).

    All index arrays are int32 (graphs up to 2**31 edges; enable
    jax_enable_x64 for larger — host-side construction is already int64).
    """

    n: int
    n_pad: int
    out_indptr: jax.Array   # int32[n_pad+1]
    out_indices: jax.Array  # int32[E]
    in_indptr: jax.Array
    in_indices: jax.Array
    out_src: jax.Array      # int32[E] edge-parallel CSR sources
    in_child: jax.Array     # int32[E] edge-parallel CSC rows (children)

    @property
    def out_deg(self):
        return jnp.diff(self.out_indptr).astype(jnp.int32)

    @property
    def in_deg(self):
        return jnp.diff(self.in_indptr).astype(jnp.int32)


def build_local_graph(csr: CSRGraph, csc: CSRGraph) -> LocalGraph:
    n = csr.num_vertices
    n_pad = bitmap.num_words(n) * bitmap.WORD_BITS

    def pad_ptr(indptr):
        return np.concatenate(
            [indptr, np.full(n_pad - n, indptr[-1], dtype=indptr.dtype)])

    return LocalGraph(
        n=n, n_pad=n_pad,
        out_indptr=jnp.asarray(pad_ptr(csr.indptr).astype(np.int32)),
        out_indices=jnp.asarray(csr.indices),
        in_indptr=jnp.asarray(pad_ptr(csc.indptr).astype(np.int32)),
        in_indices=jnp.asarray(csc.indices),
        out_src=jnp.asarray(edge_sources(csr)),
        in_child=jnp.asarray(edge_sources(csc)),
    )


# ---------------------------------------------------------------------------
# Dense (edge-parallel) steps: O(E) work, trivially correct, fully jit.
# ---------------------------------------------------------------------------

def _dense_step(g: LocalGraph, frontier_w, visited_w):
    """One level expansion; returns candidate bitmap words (global)."""
    fmask = bitmap.unpack(frontier_w, g.n_pad)
    msg = fmask[g.out_src]                       # active source per CSR edge
    cand = jnp.zeros((g.n_pad,), jnp.bool_).at[g.out_indices].max(msg)
    return bitmap.pack(cand)


def bfs_reference(g: LocalGraph, root: int, max_iters: int | None = None):
    """Fully-jit Algorithm 2 loop (dense steps).  Returns level int32[n]."""
    nw = bitmap.num_words(g.n_pad)
    max_iters = max_iters or g.n_pad

    def cond(state):
        frontier, visited, level, lvl = state
        return (bitmap.popcount(frontier) > 0) & (lvl < max_iters)

    def body(state):
        frontier, visited, level, lvl = state
        cand = _dense_step(g, frontier, visited)
        new = cand & ~visited                     # P3: next |= cand & ~visited
        visited = visited | new
        new_mask = bitmap.unpack(new, g.n_pad)
        level = jnp.where(new_mask, lvl + 1, level)
        return new, visited, level, lvl + 1

    frontier0 = bitmap.from_indices_dense(jnp.array([root]), g.n_pad)
    visited0 = frontier0
    level0 = jnp.full((g.n_pad,), INF, jnp.int32).at[root].set(0)
    frontier, visited, level, lvl = jax.lax.while_loop(
        cond, body, (frontier0, visited0, level0, jnp.int32(0)))
    return level[: g.n]


# ---------------------------------------------------------------------------
# Work-efficient gather pipeline (P1 -> P2 -> P3), mirroring the PE stages.
# ---------------------------------------------------------------------------

def compact_indices(mask: jax.Array, cap: int) -> tuple[jax.Array, jax.Array]:
    """P1 workload prep: indices of set bits, padded with -1 to ``cap``."""
    idx = jnp.nonzero(mask, size=cap, fill_value=-1)[0]
    return idx.astype(jnp.int32), jnp.sum(mask, dtype=jnp.int32)


def expand_edges(active: jax.Array, indptr: jax.Array, indices: jax.Array,
                 budget: int):
    """P2 neighbor gather: flatten the neighbor lists of ``active`` vertices.

    Returns (sources, neighbors, valid, total_edges).  ``total_edges`` may
    exceed ``budget`` — the caller must treat that as overflow and retry with
    a bigger budget (the HBM-reader queue depth analogue).
    """
    a = jnp.maximum(active, 0)
    deg = (indptr[a + 1] - indptr[a]) * (active >= 0)
    cum = jnp.cumsum(deg)
    total = cum[-1]
    e = jnp.arange(budget, dtype=jnp.int32)
    owner = jnp.searchsorted(cum, e, side="right").astype(jnp.int32)
    owner_c = jnp.minimum(owner, active.shape[0] - 1)
    start = cum[owner_c] - deg[owner_c]
    src = active[owner_c]
    eidx = indptr[jnp.maximum(src, 0)] + (e - start)
    valid = e < total
    nbr = indices[jnp.where(valid, eidx, 0)]
    return (jnp.where(valid, src, -1),
            jnp.where(valid, nbr, -1).astype(jnp.int32), valid, total)


def _p3_update(cand_w, visited_w, use_pallas: bool):
    """P3 result writing: fused Pallas kernel or plain jnp (same semantics)."""
    if use_pallas:
        from repro.kernels import ops as kops
        new, vis2, _ = kops.fused_frontier_update(cand_w, visited_w)
        return new, vis2
    new = cand_w & ~visited_w
    return new, visited_w | new


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def push_step(g: LocalGraph, frontier_w, visited_w, budget: int,
              use_pallas: bool = False):
    """Push iteration: expand out-lists of frontier, filter by visited."""
    fmask = bitmap.unpack(frontier_w, g.n_pad)
    active, n_f = compact_indices(fmask, g.n_pad)
    _, nbr, valid, total = expand_edges(active, g.out_indptr, g.out_indices,
                                        budget)
    unvisited = ~bitmap.test_bits(visited_w, jnp.maximum(nbr, 0)) & valid
    cand = bitmap.from_indices_dense(jnp.where(unvisited, nbr, -1), g.n_pad)
    new, vis2 = _p3_update(cand, visited_w, use_pallas)
    return new, vis2, total, total > budget


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def pull_step(g: LocalGraph, frontier_w, visited_w, budget: int,
              use_pallas: bool = False):
    """Pull iteration: expand in-lists of unvisited, test frontier bit."""
    umask = ~bitmap.unpack(visited_w, g.n_pad)
    unvisited, _ = compact_indices(umask, g.n_pad)
    child, parent, valid, total = expand_edges(
        unvisited, g.in_indptr, g.in_indices, budget)
    hit = bitmap.test_bits(frontier_w, jnp.maximum(parent, 0)) & valid
    cand = bitmap.from_indices_dense(jnp.where(hit, child, -1), g.n_pad)
    new, vis2 = _p3_update(cand, visited_w, use_pallas)
    return new, vis2, total, total > budget


@jax.jit
def _iter_stats(g: LocalGraph, frontier_w, visited_w):
    fmask = bitmap.unpack(frontier_w, g.n_pad)
    umask = ~bitmap.unpack(visited_w, g.n_pad)
    n_f = jnp.sum(fmask, dtype=jnp.int32)
    m_f = jnp.sum(jnp.where(fmask, g.out_deg, 0), dtype=jnp.int32)
    m_u = jnp.sum(jnp.where(umask, g.in_deg, 0), dtype=jnp.int32)
    n_u = jnp.sum(umask, dtype=jnp.int32)
    return n_f, m_f, m_u, n_u


@dataclasses.dataclass
class BFSResult:
    level: np.ndarray
    iterations: int
    edges_inspected: int
    push_iters: int
    pull_iters: int
    traversed_edges: int
    seconds: float

    @property
    def gteps(self) -> float:
        return self.traversed_edges / max(self.seconds, 1e-12) / 1e9


class BFSRunner:
    """Python-driven hybrid BFS with budgeted gather steps (bench engine)."""

    def __init__(self, g: LocalGraph, sched: SchedulerConfig | None = None,
                 init_budget: int = 1 << 15, use_pallas: bool = False):
        self.g = g
        self.sched = sched or SchedulerConfig()
        self.init_budget = init_budget
        self.use_pallas = use_pallas

    def run(self, root: int, time_it: bool = False) -> BFSResult:
        g = self.g
        frontier = bitmap.from_indices_dense(jnp.array([root]), g.n_pad)
        visited = frontier
        level = jnp.full((g.n_pad,), INF, jnp.int32).at[root].set(0)
        mode = jnp.int32(PUSH)
        lvl = 0
        inspected = 0
        push_iters = pull_iters = 0
        budget = self.init_budget
        t0 = time.perf_counter()
        while True:
            n_f, m_f, m_u, n_u = _iter_stats(g, frontier, visited)
            if int(n_f) == 0:
                break
            mode = choose_mode(self.sched, mode, n_f, m_f, m_u, g.n, n_u)
            step = push_step if int(mode) == PUSH else pull_step
            need = int(m_f) if int(mode) == PUSH else int(m_u)
            while budget < min(need, g.out_indices.shape[0] + 1):
                budget *= 2
            # retry from the PRE-step visited: an overflowed (truncated)
            # step may have committed a partial discovery set
            vis0 = visited
            new, visited, total, overflow = step(g, frontier, vis0, budget,
                                                 self.use_pallas)
            while bool(overflow):   # HBM-reader queue overflow: deepen, retry
                budget *= 2
                new, visited, total, overflow = step(g, frontier, vis0,
                                                     budget, self.use_pallas)
            new_mask = bitmap.unpack(new, g.n_pad)
            level = jnp.where(new_mask, lvl + 1, level)
            frontier = new
            lvl += 1
            inspected += int(total)
            if int(mode) == PUSH:
                push_iters += 1
            else:
                pull_iters += 1
        level.block_until_ready()
        dt = time.perf_counter() - t0
        level_np = np.asarray(level[: g.n])
        # GTEPS metric per paper §VI-A: sum of outgoing neighbor-list lengths
        # of all visited vertices; each edge counted once.
        out_deg = np.asarray(jnp.diff(g.out_indptr))[: g.n]
        traversed = count_traversed_edges(out_deg, level_np)
        return BFSResult(level=level_np, iterations=lvl,
                         edges_inspected=inspected, push_iters=push_iters,
                         pull_iters=pull_iters, traversed_edges=traversed,
                         seconds=dt)


# ---------------------------------------------------------------------------
# Batched multi-source BFS (MS-BFS): B concurrent traversals over one graph.
#
# Frontier/seen state is a per-vertex SOURCE mask — bit b of row v says
# "source b has reached v" — packed into uint32[n_pad, ceil(B/32)] words
# (bitmap.pack_rows).  Every CSR/CSC edge read is shared by the whole batch:
# propagating along an edge is one 32/64-bit OR instead of B separate
# traversals, the software analogue of keeping all HBM pseudo-channels busy
# with concurrent queries (GraphScale; Then et al., VLDB'14).
# ---------------------------------------------------------------------------

def _ms_init(g: LocalGraph, roots: jax.Array):
    b = roots.shape[0]
    planes = jnp.zeros((g.n_pad, b), jnp.bool_)
    planes = planes.at[roots, jnp.arange(b)].set(True)
    frontier = bitmap.pack_rows(planes)
    level = jnp.full((g.n_pad, b), INF, jnp.int32)
    level = level.at[roots, jnp.arange(b)].set(0)
    return frontier, frontier, level


def _ms_dense_step(g: LocalGraph, frontier_w):
    """One batched level expansion; returns candidate plane words."""
    fmask = bitmap.unpack_rows(frontier_w)        # [n_pad, B]
    msg = fmask[g.out_src]                        # [E, B] — shared edge read
    cand = jnp.zeros((g.n_pad, fmask.shape[1]),
                     jnp.bool_).at[g.out_indices].max(msg)
    return bitmap.pack_rows(cand)


def msbfs_reference(g: LocalGraph, roots, max_iters: int | None = None):
    """Fully-jit dense MS-BFS loop.  Returns level int32[B, n]."""
    roots = jnp.asarray(roots, jnp.int32)
    max_iters = max_iters or g.n_pad
    frontier0, seen0, level0 = _ms_init(g, roots)

    def cond(state):
        frontier, seen, level, lvl = state
        return (bitmap.popcount(frontier) > 0) & (lvl < max_iters)

    def body(state):
        frontier, seen, level, lvl = state
        cand = _ms_dense_step(g, frontier)
        new = cand & ~seen
        seen = seen | new
        new_mask = bitmap.unpack_rows(new, roots.shape[0])
        level = jnp.where(new_mask, lvl + 1, level)
        return new, seen, level, lvl + 1

    frontier, seen, level, lvl = jax.lax.while_loop(
        cond, body, (frontier0, seen0, level0, jnp.int32(0)))
    return level[: g.n].T


def _p3_update_ms(cand_w, seen_w, use_pallas: bool):
    """Batched P3: fused per-plane Pallas kernel or plain jnp."""
    if use_pallas:
        from repro.kernels import ops as kops
        new_t, seen_t, _ = kops.fused_frontier_update_batch(
            cand_w.T, seen_w.T)       # planes-major for the kernel grid
        return new_t.T, seen_t.T
    new = cand_w & ~seen_w
    return new, seen_w | new


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def ms_push_step(g: LocalGraph, frontier_w, seen_w, budget: int,
                 use_pallas: bool = False):
    """Batched push: expand out-lists of any-source frontier vertices; each
    gathered edge carries the full source mask of its endpoint."""
    nb = frontier_w.shape[1] * bitmap.WORD_BITS
    fmask = bitmap.unpack_rows(frontier_w)            # [n_pad, B']
    any_f = bitmap.any_rows(frontier_w)
    active, _ = compact_indices(any_f, g.n_pad)
    src, nbr, valid, total = expand_edges(active, g.out_indptr,
                                          g.out_indices, budget)
    msg = fmask[jnp.maximum(src, 0)] & valid[:, None]  # [budget, B']
    tgt = jnp.where(valid, nbr, g.n_pad)
    cand = jnp.zeros((g.n_pad + 1, nb), jnp.bool_)
    cand = cand.at[tgt].max(msg, mode="drop")[:-1]
    cand_w = bitmap.pack_rows(cand)
    new, seen2 = _p3_update_ms(cand_w, seen_w, use_pallas)
    return new, seen2, total, total > budget


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def ms_pull_step(g: LocalGraph, frontier_w, seen_w, budget: int,
                 use_pallas: bool = False):
    """Batched pull: vertices unseen by SOME source read their in-lists once
    and OR their parents' frontier masks."""
    nb = frontier_w.shape[1] * bitmap.WORD_BITS
    pmask = bitmap.plane_mask(nb)
    fmask = bitmap.unpack_rows(frontier_w)
    un_any = bitmap.any_rows(~seen_w & pmask)
    active, _ = compact_indices(un_any, g.n_pad)
    child, parent, valid, total = expand_edges(active, g.in_indptr,
                                               g.in_indices, budget)
    msg = fmask[jnp.maximum(parent, 0)] & valid[:, None]
    tgt = jnp.where(valid, child, g.n_pad)
    cand = jnp.zeros((g.n_pad + 1, nb), jnp.bool_)
    cand = cand.at[tgt].max(msg, mode="drop")[:-1]
    cand_w = bitmap.pack_rows(cand)
    new, seen2 = _p3_update_ms(cand_w, seen_w, use_pallas)
    return new, seen2, total, total > budget


@jax.jit
def _ms_iter_stats(g: LocalGraph, frontier_w, seen_w):
    nb = frontier_w.shape[1] * bitmap.WORD_BITS
    pmask = bitmap.plane_mask(nb)
    any_f = bitmap.any_rows(frontier_w)
    un_any = bitmap.any_rows(~seen_w & pmask)
    n_f = jnp.sum(any_f, dtype=jnp.int32)
    m_f = jnp.sum(jnp.where(any_f, g.out_deg, 0), dtype=jnp.int32)
    m_u = jnp.sum(jnp.where(un_any, g.in_deg, 0), dtype=jnp.int32)
    n_u = jnp.sum(un_any, dtype=jnp.int32)
    return n_f, m_f, m_u, n_u


@dataclasses.dataclass
class MSBFSResult:
    levels: np.ndarray          # int32[B, n] — one level row per source
    batch: int
    iterations: int
    edges_inspected: int
    push_iters: int
    pull_iters: int
    traversed_edges: int        # summed over all sources (paper §VI-A metric)
    seconds: float

    @property
    def aggregate_teps(self) -> float:
        return self.traversed_edges / max(self.seconds, 1e-12)

    @property
    def gteps(self) -> float:
        return self.aggregate_teps / 1e9


class MultiSourceBFSRunner:
    """Python-driven hybrid MS-BFS over a batch of roots (query engine).

    The per-iteration structure matches ``BFSRunner`` (stats -> mode ->
    budgeted gather step -> P3) with all three bitmaps widened to one
    bit-plane per source; direction choice uses any-source frontier /
    any-source-unseen statistics.
    """

    def __init__(self, g: LocalGraph, sched: SchedulerConfig | None = None,
                 init_budget: int = 1 << 15, use_pallas: bool = False):
        self.g = g
        self.sched = sched or SchedulerConfig()
        self.init_budget = init_budget
        self.use_pallas = use_pallas

    def run(self, roots, time_it: bool = False) -> MSBFSResult:
        g = self.g
        # validate BEFORE the int32 cast: a >= 2**31 root must error, not wrap
        roots = validate_roots(np.asarray(roots), g.n).astype(np.int32)
        b = int(roots.size)
        frontier, seen, level = _ms_init(g, jnp.asarray(roots))
        mode = jnp.int32(PUSH)
        lvl = 0
        inspected = 0
        push_iters = pull_iters = 0
        budget = self.init_budget
        t0 = time.perf_counter()
        while True:
            n_f, m_f, m_u, n_u = _ms_iter_stats(g, frontier, seen)
            if int(n_f) == 0:
                break
            mode = choose_mode(self.sched, mode, n_f, m_f, m_u, g.n, n_u)
            step = ms_push_step if int(mode) == PUSH else ms_pull_step
            need = int(m_f) if int(mode) == PUSH else int(m_u)
            while budget < min(need, g.out_indices.shape[0] + 1):
                budget *= 2
            # retry from the PRE-step seen: an overflowed (truncated) step
            # may have committed a partial discovery set
            seen0 = seen
            new, seen, total, overflow = step(g, frontier, seen0, budget,
                                              self.use_pallas)
            while bool(overflow):   # HBM-reader queue overflow: deepen, retry
                budget *= 2
                new, seen, total, overflow = step(g, frontier, seen0, budget,
                                                  self.use_pallas)
            new_mask = bitmap.unpack_rows(new, b)
            level = jnp.where(new_mask, lvl + 1, level)
            frontier = new
            lvl += 1
            inspected += int(total)
            if int(mode) == PUSH:
                push_iters += 1
            else:
                pull_iters += 1
        level.block_until_ready()
        dt = time.perf_counter() - t0
        levels = np.asarray(level[: g.n]).T        # [B, n]
        out_deg = np.asarray(jnp.diff(g.out_indptr))[: g.n]
        traversed = count_traversed_edges(out_deg, levels)
        return MSBFSResult(levels=levels, batch=b, iterations=lvl,
                           edges_inspected=inspected, push_iters=push_iters,
                           pull_iters=pull_iters, traversed_edges=traversed,
                           seconds=dt)


def validate_roots(roots: np.ndarray, num_vertices: int) -> np.ndarray:
    """Reject malformed MS-BFS root batches with a ``ValueError``.

    A negative or >= |V| root would otherwise scatter silently out of
    bounds (JAX clips/drops out-of-range indices), yielding a wrong answer
    instead of an error.  Duplicate roots ARE allowed — each occupies its
    own bit-plane slot and resolves independently.
    """
    roots = np.asarray(roots)
    if roots.ndim != 1 or roots.size == 0:
        raise ValueError(
            f"roots must be a non-empty 1-D array, got shape {roots.shape}")
    if not np.issubdtype(roots.dtype, np.integer):
        # a float/bool root would pass the range check and then be
        # silently truncated by the engine's integer cast
        raise ValueError(f"roots must be integers, got dtype {roots.dtype}")
    if ((roots < 0) | (roots >= num_vertices)).any():
        bad = roots[(roots < 0) | (roots >= num_vertices)]
        raise ValueError(
            f"roots out of range [0, {num_vertices}): {bad.tolist()[:8]}")
    return roots


def engine_num_vertices(engine) -> int | None:
    """|V| of the graph a BFS engine serves (duck-typed), or None.

    Recognizes the local runners (``.g`` is a :class:`LocalGraph`) and the
    distributed engine (``.pg`` is a ``PartitionedGraph``).
    """
    g = getattr(engine, "g", None)
    if g is not None:
        return int(g.n)
    pg = getattr(engine, "pg", None)
    if pg is not None:
        return int(pg.num_vertices)
    return None


def count_traversed_edges(out_deg: np.ndarray, levels: np.ndarray) -> int:
    """Paper §VI-A GTEPS numerator: out-degrees of reached vertices, summed
    over every source row of ``levels`` ([n] or [B, n])."""
    levels = np.atleast_2d(levels)
    return int(sum(out_deg[levels[i] < int(INF)].sum()
                   for i in range(levels.shape[0])))


def bfs_oracle(csr: CSRGraph, root: int) -> np.ndarray:
    """Pure-python BFS (Algorithm 1) — the correctness oracle."""
    from collections import deque
    level = np.full(csr.num_vertices, int(INF), dtype=np.int64)
    level[root] = 0
    q = deque([root])
    while q:
        v = q.popleft()
        for u in csr.neighbors(v):
            if level[u] == int(INF):
                level[u] = level[v] + 1
                q.append(int(u))
    return level
