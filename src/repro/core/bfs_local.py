"""Single-device BFS engine implementing the paper's Algorithm 2.

Three bitmaps (current frontier / next frontier / visited) + a level array.
Two execution paths:

* ``bfs_reference`` — fully-jit `lax.while_loop`, edge-parallel (dense) steps.
  This is the correctness oracle-adjacent path used by tests and by the
  distributed engine's per-shard step.
* ``BFSRunner`` — work-efficient gather path mirroring the hardware pipeline
  P1 (workload prep: frontier compaction), P2 (neighbor checking: CSR/CSC
  gather + bitmap tests), P3 (result writing: fused bitmap update).  It
  counts *inspected edges* per mode, which is what the paper's Fig. 8/10
  comparisons measure, and drives GTEPS benchmarks.

The batched multi-source engines (MS-BFS, CC, SSSP) live in
``repro.core.vertex_program``; this module provides the shared primitives
they build on (``LocalGraph``, ``compact_indices``, ``expand_edges``, the
``SV_*`` statvec layout, ``validate_roots``) plus the single-source
pipeline.  Both drivers share the one-sync-per-level protocol: every step
returns a stacked int32 stats vector fused into the step itself, so each
level pays exactly ONE blocking device->host transfer.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap
from repro.core.scheduler import PUSH, SchedulerConfig, choose_mode_host
from repro.graph.csr import CSRGraph, edge_sources

INF = jnp.int32(2 ** 30)

# Layout of the per-level fused stats vector (int32[7]) every step returns:
# next-frontier stats for the Scheduler, this step's edge total + overflow
# flag, and the new-discovery popcount — ONE device->host transfer per level.
SV_NF, SV_MF, SV_MU, SV_NU, SV_TOTAL, SV_OVERFLOW, SV_COUNT = range(7)


@partial(jax.tree_util.register_dataclass,
         data_fields=("out_indptr", "out_indices", "in_indptr", "in_indices",
                      "out_src", "in_child", "out_deg", "in_deg",
                      "in_seg_first", "in_seg_end"),
         meta_fields=("n", "n_pad"))
@dataclasses.dataclass(frozen=True)
class LocalGraph:
    """Device-resident graph arrays (vertex space padded to words).

    All index arrays are int32 (graphs up to 2**31 edges; enable
    jax_enable_x64 for larger — host-side construction is already int64).
    Degrees are precomputed once at build time (they feed the per-level
    scheduler stats; re-deriving them with ``jnp.diff`` every level was
    pure waste), as are the CSC segment descriptors the scan-based pull
    propagate uses (``in_seg_first``/``in_seg_end``).
    """

    n: int
    n_pad: int
    out_indptr: jax.Array   # int32[n_pad+1]
    out_indices: jax.Array  # int32[E]
    in_indptr: jax.Array
    in_indices: jax.Array
    out_src: jax.Array      # int32[E] edge-parallel CSR sources
    in_child: jax.Array     # int32[E] edge-parallel CSC rows (children)
    out_deg: jax.Array      # int32[n_pad] stored out-degrees
    in_deg: jax.Array       # int32[n_pad] stored in-degrees
    in_seg_first: jax.Array  # bool[E]  e starts a child's in-list
    in_seg_end: jax.Array    # int32[n_pad] last in-edge per child (-1: none)


def build_local_graph(csr: CSRGraph, csc: CSRGraph) -> LocalGraph:
    n = csr.num_vertices
    n_pad = bitmap.num_words(n) * bitmap.WORD_BITS

    def pad_ptr(indptr):
        return np.concatenate(
            [indptr, np.full(n_pad - n, indptr[-1], dtype=indptr.dtype)])

    out_ptr = pad_ptr(csr.indptr)
    in_ptr = pad_ptr(csc.indptr)
    in_deg = np.diff(in_ptr)
    e_in = int(csc.indices.shape[0])
    in_first = np.zeros(e_in, dtype=bool)
    in_first[in_ptr[:-1][in_deg > 0]] = True
    in_end = np.where(in_deg > 0, in_ptr[1:] - 1, -1)

    return LocalGraph(
        n=n, n_pad=n_pad,
        out_indptr=jnp.asarray(out_ptr.astype(np.int32)),
        out_indices=jnp.asarray(csr.indices),
        in_indptr=jnp.asarray(in_ptr.astype(np.int32)),
        in_indices=jnp.asarray(csc.indices),
        out_src=jnp.asarray(edge_sources(csr)),
        in_child=jnp.asarray(edge_sources(csc)),
        out_deg=jnp.asarray(np.diff(out_ptr).astype(np.int32)),
        in_deg=jnp.asarray(in_deg.astype(np.int32)),
        in_seg_first=jnp.asarray(in_first),
        in_seg_end=jnp.asarray(in_end.astype(np.int32)),
    )


# ---------------------------------------------------------------------------
# Dense (edge-parallel) steps: O(E) work, trivially correct, fully jit.
# ---------------------------------------------------------------------------

def _dense_step(g: LocalGraph, frontier_w, visited_w):
    """One level expansion; returns candidate bitmap words (global)."""
    fmask = bitmap.unpack(frontier_w, g.n_pad)
    msg = fmask[g.out_src]                       # active source per CSR edge
    cand = jnp.zeros((g.n_pad,), jnp.bool_).at[g.out_indices].max(msg)
    return bitmap.pack(cand)


def bfs_reference(g: LocalGraph, root: int, max_iters: int | None = None):
    """Fully-jit Algorithm 2 loop (dense steps).  Returns level int32[n]."""
    max_iters = max_iters or g.n_pad

    def cond(state):
        frontier, visited, level, lvl = state
        return (bitmap.popcount(frontier) > 0) & (lvl < max_iters)

    def body(state):
        frontier, visited, level, lvl = state
        cand = _dense_step(g, frontier, visited)
        new = cand & ~visited                     # P3: next |= cand & ~visited
        visited = visited | new
        new_mask = bitmap.unpack(new, g.n_pad)
        level = jnp.where(new_mask, lvl + 1, level)
        return new, visited, level, lvl + 1

    frontier0 = bitmap.from_indices_dense(jnp.array([root]), g.n_pad)
    visited0 = frontier0
    level0 = jnp.full((g.n_pad,), INF, jnp.int32).at[root].set(0)
    frontier, visited, level, lvl = jax.lax.while_loop(
        cond, body, (frontier0, visited0, level0, jnp.int32(0)))
    return level[: g.n]


# ---------------------------------------------------------------------------
# Work-efficient gather pipeline (P1 -> P2 -> P3), mirroring the PE stages.
# ---------------------------------------------------------------------------

def compact_indices(mask: jax.Array, cap: int) -> tuple[jax.Array, jax.Array]:
    """P1 workload prep: indices of set bits, padded with -1 to ``cap``."""
    idx = jnp.nonzero(mask, size=cap, fill_value=-1)[0]
    return idx.astype(jnp.int32), jnp.sum(mask, dtype=jnp.int32)


def expand_edges(active: jax.Array, indptr: jax.Array, indices: jax.Array,
                 budget: int):
    """P2 neighbor gather: flatten the neighbor lists of ``active`` vertices.

    Returns (sources, neighbors, valid, total_edges).  ``total_edges`` may
    exceed ``budget`` — the caller must treat that as overflow and retry with
    a bigger budget (the HBM-reader queue depth analogue).
    """
    a = jnp.maximum(active, 0)
    deg = (indptr[a + 1] - indptr[a]) * (active >= 0)
    cum = jnp.cumsum(deg)
    total = cum[-1]
    e = jnp.arange(budget, dtype=jnp.int32)
    owner = jnp.searchsorted(cum, e, side="right").astype(jnp.int32)
    owner_c = jnp.minimum(owner, active.shape[0] - 1)
    start = cum[owner_c] - deg[owner_c]
    src = active[owner_c]
    eidx = indptr[jnp.maximum(src, 0)] + (e - start)
    valid = e < total
    nbr = indices[jnp.where(valid, eidx, 0)]
    return (jnp.where(valid, src, -1),
            jnp.where(valid, nbr, -1).astype(jnp.int32), valid, total)


def _p3_update(cand_w, visited_w, use_pallas: bool):
    """P3 result writing: fused Pallas kernel or plain jnp (same semantics)."""
    if use_pallas:
        from repro.kernels import ops as kops
        new, vis2, _ = kops.fused_frontier_update(cand_w, visited_w)
        return new, vis2
    new = cand_w & ~visited_w
    return new, visited_w | new


def _statvec(g: LocalGraph, new_w, visited_w, total, overflow):
    """Fused per-level stats (single-source): one stacked int32[7]."""
    fmask = bitmap.unpack(new_w, g.n_pad)
    umask = ~bitmap.unpack(visited_w, g.n_pad)
    return jnp.stack([
        jnp.sum(fmask, dtype=jnp.int32),
        jnp.sum(jnp.where(fmask, g.out_deg, 0), dtype=jnp.int32),
        jnp.sum(jnp.where(umask, g.in_deg, 0), dtype=jnp.int32),
        jnp.sum(umask, dtype=jnp.int32),
        jnp.asarray(total, jnp.int32),
        jnp.asarray(overflow, jnp.int32),
        bitmap.popcount(new_w),
    ])


@jax.jit
def _sbfs_init(g: LocalGraph, roots):
    frontier = bitmap.from_indices_dense(roots, g.n_pad)
    level = jnp.full((g.n_pad,), INF, jnp.int32).at[roots[0]].set(0)
    return (frontier, frontier, level,
            _statvec(g, frontier, frontier, 0, 0))


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def push_step(g: LocalGraph, frontier_w, visited_w, level, lvl, budget: int,
              use_pallas: bool = False):
    """Push iteration: expand out-lists of frontier, filter by visited.

    Level update and next-level stats are folded in; returns
    (new, visited, level, statvec) — the driver fetches only ``statvec``.
    """
    fmask = bitmap.unpack(frontier_w, g.n_pad)
    active, _ = compact_indices(fmask, g.n_pad)
    _, nbr, valid, total = expand_edges(active, g.out_indptr, g.out_indices,
                                        budget)
    unvisited = ~bitmap.test_bits(visited_w, jnp.maximum(nbr, 0)) & valid
    cand = bitmap.from_indices_dense(jnp.where(unvisited, nbr, -1), g.n_pad)
    new, vis2 = _p3_update(cand, visited_w, use_pallas)
    level2 = jnp.where(bitmap.unpack(new, g.n_pad), lvl + 1, level)
    return new, vis2, level2, _statvec(g, new, vis2, total, total > budget)


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def pull_step(g: LocalGraph, frontier_w, visited_w, level, lvl, budget: int,
              use_pallas: bool = False):
    """Pull iteration: expand in-lists of unvisited, test frontier bit."""
    umask = ~bitmap.unpack(visited_w, g.n_pad)
    unvisited, _ = compact_indices(umask, g.n_pad)
    child, parent, valid, total = expand_edges(
        unvisited, g.in_indptr, g.in_indices, budget)
    hit = bitmap.test_bits(frontier_w, jnp.maximum(parent, 0)) & valid
    cand = bitmap.from_indices_dense(jnp.where(hit, child, -1), g.n_pad)
    new, vis2 = _p3_update(cand, visited_w, use_pallas)
    level2 = jnp.where(bitmap.unpack(new, g.n_pad), lvl + 1, level)
    return new, vis2, level2, _statvec(g, new, vis2, total, total > budget)


@dataclasses.dataclass
class BFSResult:
    level: np.ndarray
    iterations: int
    edges_inspected: int
    push_iters: int
    pull_iters: int
    traversed_edges: int
    seconds: float
    host_transfers: int = 0     # blocking device->host fetches during run

    @property
    def gteps(self) -> float:
        return self.traversed_edges / max(self.seconds, 1e-12) / 1e9


class BFSRunner:
    """Python-driven hybrid BFS with budgeted gather steps (bench engine).

    One-sync-per-level driver: every step returns its successor's stats as
    a stacked int32 vector, so the loop performs exactly one blocking
    device->host transfer per level (plus one for the initial frontier and
    one final level-array readback).
    """

    def __init__(self, g: LocalGraph, sched: SchedulerConfig | None = None,
                 init_budget: int = 1 << 15, use_pallas: bool = False):
        self.g = g
        self.sched = sched or SchedulerConfig()
        self.init_budget = init_budget
        self.use_pallas = use_pallas
        self._transfers = 0
        # fetched once here so the GTEPS accounting after each run is not
        # an extra (uncounted) device->host transfer
        self._out_deg_np = np.asarray(g.out_deg)[: g.n]

    @property
    def num_vertices(self) -> int:
        return int(self.g.n)

    @property
    def out_deg(self) -> np.ndarray:
        """Out-degrees [n] (the engine protocol's TEPS numerator input)."""
        return self._out_deg_np

    def _fetch(self, arr) -> np.ndarray:
        self._transfers += 1
        return np.asarray(arr)

    def run(self, root: int) -> BFSResult:
        g = self.g
        self._transfers = 0
        t0 = time.perf_counter()
        frontier, visited, level, statvec = _sbfs_init(
            g, jnp.asarray([root], jnp.int32))
        sv = self._fetch(statvec)
        mode = PUSH
        lvl = 0
        inspected = 0
        push_iters = pull_iters = 0
        # no point budgeting past the whole edge array (keeps the budgeted
        # kernels small on tiny graphs); the overflow loop still deepens
        budget = min(self.init_budget,
                     max(g.out_indices.shape[0], g.in_indices.shape[0]) + 1)
        while int(sv[SV_NF]) > 0:
            mode = choose_mode_host(self.sched, mode, int(sv[SV_NF]),
                                    int(sv[SV_MF]), int(sv[SV_MU]), g.n,
                                    int(sv[SV_NU]))
            step = push_step if mode == PUSH else pull_step
            need = int(sv[SV_MF]) if mode == PUSH else int(sv[SV_MU])
            cap = (g.out_indices if mode == PUSH else g.in_indices).shape[0]
            while budget < min(need, cap + 1):
                budget *= 2
            # retry from the PRE-step visited: an overflowed (truncated)
            # step may have committed a partial discovery set
            state0 = (frontier, visited, level)
            frontier, visited, level, statvec = step(
                g, *state0, np.int32(lvl), budget, self.use_pallas)
            sv = self._fetch(statvec)
            while bool(sv[SV_OVERFLOW]):   # HBM-reader overflow: deepen
                budget *= 2
                frontier, visited, level, statvec = step(
                    g, *state0, np.int32(lvl), budget, self.use_pallas)
                sv = self._fetch(statvec)
            lvl += 1
            inspected += int(sv[SV_TOTAL])
            if mode == PUSH:
                push_iters += 1
            else:
                pull_iters += 1
        level.block_until_ready()
        dt = time.perf_counter() - t0
        level_np = self._fetch(level[: g.n])
        # GTEPS metric per paper §VI-A: sum of outgoing neighbor-list lengths
        # of all visited vertices; each edge counted once.
        traversed = count_traversed_edges(self._out_deg_np, level_np)
        return BFSResult(level=level_np, iterations=lvl,
                         edges_inspected=inspected, push_iters=push_iters,
                         pull_iters=pull_iters, traversed_edges=traversed,
                         seconds=dt, host_transfers=self._transfers)


# ---------------------------------------------------------------------------
# Batched multi-source traversal (MS-BFS and friends) lives in
# ``repro.core.vertex_program``: the packed plane exchange, the hybrid
# scheduler loop and the one-sync-per-level statvec protocol were factored
# into a generic vertex-program engine there (BFS / CC / SSSP
# instantiations).  This module keeps the single-source pipeline plus the
# shared primitives the engine builds on (LocalGraph, compact_indices,
# expand_edges, the statvec layout, validate_roots).
# ---------------------------------------------------------------------------

@runtime_checkable
class BFSEngine(Protocol):
    """Minimal contract the serving layers rely on.

    Any batched vertex-program query engine exposes the number of vertices
    of its resident graph, its out-degree array (the per-wave TEPS
    numerator — serving layers no longer sniff ``.g.out_deg``), and
    answers a batch of root queries with a value-rows matrix; per-run
    counters land in ``last_stats``.  ``VertexProgramRunner`` (and its
    BFS/CC/SSSP subclasses) and ``DistributedBFS`` all satisfy this —
    ``launch.dynbatch`` / ``launch.serve`` program against it instead of
    duck-typing on ``.g`` / ``.pg``.
    """

    @property
    def num_vertices(self) -> int: ...

    @property
    def out_deg(self) -> "np.ndarray | None": ...

    def run_batch(self, roots) -> np.ndarray: ...


def validate_roots(roots: np.ndarray, num_vertices: int) -> np.ndarray:
    """Reject malformed MS-BFS root batches with a ``ValueError``.

    A negative or >= |V| root would otherwise scatter silently out of
    bounds (JAX clips/drops out-of-range indices), yielding a wrong answer
    instead of an error.  Duplicate roots ARE allowed — each occupies its
    own bit-plane slot and resolves independently.
    """
    roots = np.asarray(roots)
    if roots.ndim != 1 or roots.size == 0:
        raise ValueError(
            f"roots must be a non-empty 1-D array, got shape {roots.shape}")
    if not np.issubdtype(roots.dtype, np.integer):
        # a float/bool root would pass the range check and then be
        # silently truncated by the engine's integer cast
        raise ValueError(f"roots must be integers, got dtype {roots.dtype}")
    if ((roots < 0) | (roots >= num_vertices)).any():
        bad = roots[(roots < 0) | (roots >= num_vertices)]
        raise ValueError(
            f"roots out of range [0, {num_vertices}): {bad.tolist()[:8]}")
    return roots


def engine_num_vertices(engine) -> int | None:
    """|V| of the graph a BFS engine serves, or None.

    Deprecated shim: engines now expose ``num_vertices`` directly (the
    :class:`BFSEngine` protocol); this forwards to it, keeping the old
    ``.g``/``.pg`` duck-typing as a fallback for wrapper engines that
    predate the protocol.
    """
    n = getattr(engine, "num_vertices", None)
    if n is not None:
        return int(n)
    g = getattr(engine, "g", None)
    if g is not None:
        return int(g.n)
    pg = getattr(engine, "pg", None)
    if pg is not None:
        return int(pg.num_vertices)
    return None


def count_traversed_edges(out_deg: np.ndarray, levels: np.ndarray) -> int:
    """Paper §VI-A GTEPS numerator: out-degrees of reached vertices, summed
    over every source row of ``levels`` ([n] or [B, n]) — one masked
    matvec instead of a python loop over rows."""
    levels = np.atleast_2d(np.asarray(levels))
    reached = levels < int(INF)                      # [B, n]
    return int((reached @ np.asarray(out_deg, dtype=np.int64)).sum())


def bfs_oracle(csr: CSRGraph, root: int) -> np.ndarray:
    """Pure-python BFS (Algorithm 1) — the correctness oracle."""
    from collections import deque
    level = np.full(csr.num_vertices, int(INF), dtype=np.int64)
    level[root] = 0
    q = deque([root])
    while q:
        v = q.popleft()
        for u in csr.neighbors(v):
            if level[u] == int(INF):
                level[u] = level[v] + 1
                q.append(int(u))
    return level
