"""Packed-bitmap frontier state (paper Algorithm 2).

ScalaBFS tracks vertex status with three bitmaps — ``current_frontier``,
``next_frontier``, ``visited`` — one bit per vertex, held in double-pump
BRAM on the FPGA.  The TPU analogue is a packed ``uint32`` word array that
lives in VMEM inside kernels and in device HBM between iterations.

All functions are pure-jnp and jit-safe; the Pallas kernel in
``repro.kernels.bitmap_update`` implements the fused P3 update against the
same semantics (``repro.kernels.ref`` ties them together).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def num_words(num_bits: int) -> int:
    return (num_bits + WORD_BITS - 1) // WORD_BITS


def zeros(num_bits: int) -> jax.Array:
    return jnp.zeros((num_words(num_bits),), dtype=jnp.uint32)


def from_indices(idx: jax.Array, num_bits: int) -> jax.Array:
    """Bitmap with bits ``idx`` set.  Out-of-range indices are ignored."""
    idx = jnp.asarray(idx)
    valid = (idx >= 0) & (idx < num_bits)
    word = jnp.where(valid, idx // WORD_BITS, num_words(num_bits))
    bit = (jnp.uint32(1) << (idx % WORD_BITS).astype(jnp.uint32))
    bit = jnp.where(valid, bit, 0).astype(jnp.uint32)
    out = jnp.zeros((num_words(num_bits) + 1,), dtype=jnp.uint32)
    out = _scatter_or(out, word, bit)
    return out[:-1]


def _scatter_or(words: jax.Array, word_idx: jax.Array, bits: jax.Array) -> jax.Array:
    """Scatter bitwise-OR: words[word_idx] |= bits (duplicates allowed)."""
    # Decompose into the 32 bit-planes: for plane b, set word w if any
    # scattered element targets (w, b).  at[].max on uint32 of a single bit
    # value is an OR for that bit, but two different bits in the same word
    # would take max instead of OR.  Per-plane scatter-max is exact.
    out = words
    for b in range(WORD_BITS):
        plane = bits & jnp.uint32(1 << b)
        out = out.at[word_idx].max(plane)  # max == OR for single-bit planes
    return out


def from_indices_dense(idx: jax.Array, num_bits: int) -> jax.Array:
    """Bitmap from indices via a dense boolean intermediate (fast path)."""
    dense = jnp.zeros((num_words(num_bits) * WORD_BITS,), dtype=jnp.bool_)
    valid = (idx >= 0) & (idx < num_bits)
    dense = dense.at[jnp.where(valid, idx, num_bits)].max(valid,
                                                          mode="drop")
    return pack(dense)


def pack(mask: jax.Array) -> jax.Array:
    """bool[num_bits] -> uint32[num_words] (little-endian bit order)."""
    nb = mask.shape[0]
    pad = (-nb) % WORD_BITS
    m = jnp.pad(mask, (0, pad)).reshape(-1, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(m << shifts, axis=1, dtype=jnp.uint32)


def unpack(words: jax.Array, num_bits: int | None = None) -> jax.Array:
    """uint32[num_words] -> bool[num_bits]."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & jnp.uint32(1)
    flat = bits.reshape(-1).astype(jnp.bool_)
    return flat if num_bits is None else flat[:num_bits]


def test_bits(words: jax.Array, idx: jax.Array) -> jax.Array:
    """Gathered bit test: returns bool per index."""
    w = words[idx // WORD_BITS]
    return ((w >> (idx % WORD_BITS).astype(jnp.uint32)) & 1).astype(jnp.bool_)


def popcount(words: jax.Array) -> jax.Array:
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32))


def np_unpack(words: np.ndarray, num_bits: int) -> np.ndarray:
    b = np.unpackbits(words.view(np.uint8), bitorder="little")
    return b[:num_bits].astype(bool)


# ---------------------------------------------------------------------------
# Batched (multi-source) bit-planes: one bit per BFS source, packed along the
# LAST axis.  A `[n, B]` boolean plane-set packs to uint32[n, ceil(B/32)]:
# element v holds the source-mask of vertex v, so a whole 32/64-root batch
# rides on every CSR edge read (MS-BFS sharing; Then et al., VLDB'14).
# ---------------------------------------------------------------------------

def pack_rows(mask: jax.Array) -> jax.Array:
    """bool[..., B] -> uint32[..., num_words(B)] (little-endian bit order)."""
    nb = mask.shape[-1]
    pad = (-nb) % WORD_BITS
    widths = [(0, 0)] * (mask.ndim - 1) + [(0, pad)]
    m = jnp.pad(mask, widths).reshape(
        *mask.shape[:-1], -1, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(m << shifts, axis=-1, dtype=jnp.uint32)


def unpack_rows(words: jax.Array, num_bits: int | None = None) -> jax.Array:
    """uint32[..., nw] -> bool[..., num_bits]."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(*words.shape[:-1], -1).astype(jnp.bool_)
    return flat if num_bits is None else flat[..., :num_bits]


def plane_mask(num_bits: int) -> jax.Array:
    """uint32[num_words] with the first ``num_bits`` bits set — masks the
    pad bits of the last source word (needed before complementing)."""
    bits = jnp.arange(num_words(num_bits) * WORD_BITS) < num_bits
    return pack(bits)


def pad_plane_slots(roots: np.ndarray, fill: int | None = None,
                    word_bits: int = WORD_BITS) -> tuple[np.ndarray, int]:
    """Pad a 1-D slot array so its length fills whole uint32 plane words.

    Dynamic-batching waves rarely arrive as an exact multiple of 32.  Each
    slot is an independent bit-plane and duplicate roots are legal, so the
    pad slots repeat ``fill`` (default: the first root); the packed word
    count — and therefore every jitted MS-BFS step shape — stays constant
    across wave sizes, keeping the compilation cache hot.

    Pad-slot work must stay INERT: a duplicate plane never changes the
    union frontier (its bits ride word lanes that are already set), so the
    per-level edge traffic is unchanged, and callers must both slice
    results with :func:`slice_plane_rows` AND account TEPS over the real
    requests only (``launch.dynbatch`` recounts traversed edges from the
    sliced rows for exactly this reason).  ``fill`` may name a different
    (e.g. known-isolated) vertex; it must be a non-negative integer —
    bounds against |V| are the engine's ``validate_roots`` job.  Returns
    ``(padded_roots, original_length)``.
    """
    roots = np.asarray(roots)
    if roots.ndim != 1 or roots.size == 0:
        raise ValueError(f"roots must be 1-D and non-empty, got shape "
                         f"{roots.shape}")
    if fill is not None:
        if isinstance(fill, bool) or not isinstance(fill, (int, np.integer)):
            raise TypeError(f"fill must be an integer vertex id, got "
                            f"{type(fill).__name__} ({fill!r})")
        if fill < 0:
            raise ValueError(f"fill must be non-negative, got {fill}")
    b = int(roots.size)
    pad = (-b) % word_bits
    if pad == 0:
        return roots, b
    fill_v = roots[0] if fill is None else fill
    return np.concatenate(
        [roots, np.full(pad, fill_v, dtype=roots.dtype)]), b


def slice_plane_rows(rows, b: int):
    """Drop the pad slots of :func:`pad_plane_slots` from a per-slot result
    (levels ``[B_padded, n]`` -> ``[b, n]``, or any leading-axis array)."""
    return rows[:b]


def _scatter_or_rows(words: jax.Array, row_idx: jax.Array,
                     msg: jax.Array) -> jax.Array:
    """Packed scatter-OR: ``words[row_idx[e]] |= msg[e]`` for every e.

    The jnp fallback for the fused P2->P3 Pallas propagate kernel
    (``repro.kernels.msbfs_propagate``), with identical semantics: duplicate
    target rows OR together and out-of-range rows are dropped.  ``at[].max``
    is only an OR for single-bit values, so the words are decomposed into
    bit planes first — vectorized over the 4 byte lanes of each uint32, so
    the whole scatter is ONE gather-free call of uint8 single-bit planes
    (8 planes per lane) instead of 32 sequential word-sized scatters.

    words: uint32[r, nw]   accumulator (existing bits are kept)
    row_idx: int32[m]      target row per message (OOR -> dropped)
    msg: uint32[m, nw]     packed source-mask words to OR in
    """
    r, nw = words.shape
    m = msg.shape[0]
    # negative indices would WRAP (numpy semantics), not drop — rewrite
    # them to r so mode="drop" discards them like any other OOR row
    row_idx = jnp.where(row_idx < 0, r, row_idx)
    shifts = jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8)
    def to_planes(w):
        b8 = jax.lax.bitcast_convert_type(w, jnp.uint8)      # [.., nw, 4]
        return (b8[..., None] & shifts).reshape(*w.shape[:-1], nw * 32)
    acc = to_planes(words).at[row_idx].max(to_planes(msg), mode="drop")
    bytes_ = acc.reshape(r, nw, 4, 8).sum(-1).astype(jnp.uint8)
    return jax.lax.bitcast_convert_type(bytes_, jnp.uint32).reshape(r, nw)


def segment_or_rows(msg: jax.Array, first: jax.Array) -> jax.Array:
    """Inclusive segmented OR-scan over rows of packed words.

    ``msg`` is uint32[E, nw] (one packed source-mask per edge), ``first`` is
    bool[E] marking the first edge of each contiguous segment.  Returns
    scan[E, nw] where scan[e] = OR of msg over e's segment up to e — read
    the last slot of each segment for the per-segment OR.  This is how the
    pull direction reduces each vertex's in-list without any scatter: CSC
    edges are already grouped by child, so the segment boundaries are
    static (``LocalGraph.in_seg_first`` / ``in_seg_end``).
    """
    def op(a, b):
        av, af = a
        bv, bf = b
        return jnp.where(bf[..., None], bv, av | bv), af | bf
    v, _ = jax.lax.associative_scan(op, (msg, first), axis=0)
    return v


def any_rows(words: jax.Array) -> jax.Array:
    """bool[...]: does row v have any source bit set?"""
    return jnp.any(words != 0, axis=-1)


def popcount_rows(words: jax.Array) -> jax.Array:
    """int32[...]: per-row popcount over the packed source words."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32),
                   axis=-1)
