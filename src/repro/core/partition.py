"""Horizontal hash partitioning of the vertex space (paper §IV-A/B).

ScalaBFS assigns vertex ``v`` to PE ``v % Q`` (interval hashing for load
balance) and keeps whole neighbor lists inside the owning partition
("horizontal" split of the adjacency matrix — lists are never broken, which
preserves long sequential reads from the memory channel).

On TPU we re-index vertices so that partition ``s`` owns the *contiguous*
reindexed range ``[s*Vl, (s+1)*Vl)``:

    reindex(v) = (v % Q) * Vl + v // Q           (Vl = ceil(|V|/Q))

The contiguous layout makes shard boundaries coincide with bitmap word
boundaries and with `shard_map` block sharding, while preserving the paper's
exact modulo load-balancing.  All BFS-internal IDs are reindexed; results are
mapped back at the end.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Per-shard CSR+CSC in reindexed vertex space, padded & stacked.

    All arrays have a leading shard axis Q so `shard_map` can split them.

    out_indptr : int64[Q, Vl+1]  — CSR offsets of *owned* vertices (local rows)
    out_indices: int32[Q, Eout]  — global reindexed child IDs (padded with -1)
    in_indptr  : int64[Q, Vl+1]  — CSC offsets of owned vertices
    in_indices : int32[Q, Ein]   — global reindexed parent IDs (padded with -1)
    """

    num_vertices: int            # original |V|
    num_vertices_padded: int     # Q * Vl
    num_shards: int
    verts_per_shard: int
    out_indptr: np.ndarray
    out_indices: np.ndarray
    in_indptr: np.ndarray
    in_indices: np.ndarray
    scheme: str = "hash"         # "hash" (paper) | "contiguous" (baseline)

    @property
    def num_edges(self) -> int:
        return int((self.out_indices >= 0).sum())


def reindex(v: np.ndarray, q: int, vl: int) -> np.ndarray:
    return (v % q) * vl + v // q


def unreindex(g: np.ndarray, q: int, vl: int) -> np.ndarray:
    return (g % vl) * q + g // vl


def _owned(s: int, n: int, q: int, vl: int, scheme: str) -> np.ndarray:
    if scheme == "hash":
        return np.arange(s, n, q)           # paper: VID % Q == s
    lo = min(s * vl, n)                     # baseline: contiguous intervals
    return np.arange(lo, min(lo + vl, n))


def _shard_lists(indptr: np.ndarray, indices: np.ndarray, n: int, q: int,
                 vl: int, pad_multiple: int,
                 scheme: str = "hash") -> tuple[np.ndarray, np.ndarray]:
    """Slice the neighbor-list arrays of each shard's owned vertices."""
    shard_indptr = np.zeros((q, vl + 1), dtype=np.int64)
    shard_lists = []
    for s in range(q):
        owned = _owned(s, n, q, vl, scheme)
        degs = np.diff(indptr)[owned] if owned.size else np.zeros(0, np.int64)
        ptr = np.zeros(vl + 1, dtype=np.int64)
        np.cumsum(degs, out=ptr[1: 1 + owned.size])
        if owned.size < vl:
            ptr[1 + owned.size:] = ptr[owned.size]
        shard_indptr[s] = ptr
        chunks = [indices[indptr[v]: indptr[v + 1]] for v in owned]
        shard_lists.append(np.concatenate(chunks) if chunks else
                           np.zeros(0, np.int32))
    emax = max((x.size for x in shard_lists), default=0)
    emax = ((emax + pad_multiple - 1) // pad_multiple) * pad_multiple
    emax = max(emax, pad_multiple)
    out = np.full((q, emax), -1, dtype=np.int32)
    for s, lst in enumerate(shard_lists):
        lst64 = lst.astype(np.int64)
        out[s, : lst.size] = (reindex(lst64, q, vl) if scheme == "hash"
                              else lst64)
    return shard_indptr, out


def partition_graph(csr: CSRGraph, csc: CSRGraph, num_shards: int,
                    pad_multiple: int = 128, align: int = 32,
                    scheme: str = "hash") -> PartitionedGraph:
    n = csr.num_vertices
    q = num_shards
    vl = (n + q - 1) // q
    vl = ((vl + align - 1) // align) * align   # word-align shard ranges
    out_indptr, out_indices = _shard_lists(csr.indptr, csr.indices, n, q, vl,
                                           pad_multiple, scheme)
    in_indptr, in_indices = _shard_lists(csc.indptr, csc.indices, n, q, vl,
                                         pad_multiple, scheme)
    return PartitionedGraph(
        num_vertices=n, num_vertices_padded=q * vl, num_shards=q,
        verts_per_shard=vl, out_indptr=out_indptr, out_indices=out_indices,
        in_indptr=in_indptr, in_indices=in_indices, scheme=scheme)
