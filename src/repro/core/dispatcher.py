"""Vertex dispatcher — the paper's crossbar, as mesh collectives (§IV-D).

The FPGA dispatcher routes neighbor-vertex messages to owning PEs through
either a full N×N crossbar (N² FIFOs) or a k-layer crossbar
(N = C1×…×Ck, Σ (N/Ci)·Ci² FIFOs).  On a TPU mesh the same two designs are:

* ``flat``   — one collective over the *flattened* device axis
  (`axis_name = ("pod","data","model")`): every device exchanges with all
  Q peers directly.  This is the full crossbar.
* ``staged`` — k successive collectives, one per mesh axis, with partial
  OR-combining between stages.  Stage i only exchanges along axis i
  (ICI-neighbor links on a torus), exactly the multi-layer crossbar with
  C_i = axis size.  Bytes grow by ~(1 + 1/C1 + 1/(C1·C2)) but message count
  drops from Q-1 to Σ(C_i - 1) per device and every transfer stays on one
  torus dimension.

Two message representations (see DESIGN.md §2):

* bitmap  — candidates as a packed uint32 bitmap over the global (reindexed)
  vertex space; combining = bitwise OR (subsumes the paper's conflict
  recombiner).  Delivery is an OR-reduce-scatter.
* queue   — capacity-bounded vertex-ID buckets (the literal FIFO design),
  with overflow carried to a retry round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitmap


# ---------------------------------------------------------------------------
# Bitmap dispatch: OR-reduce-scatter, flat or staged.
# ---------------------------------------------------------------------------

def or_reduce_scatter_flat(cand_words: jax.Array, axis_names: tuple[str, ...],
                           num_shards: int) -> jax.Array:
    """Full-crossbar delivery: one all-to-all over the flattened axis.

    cand_words: uint32[W] candidate bitmap over the global vertex space.
    Returns uint32[W / Q]: the OR over all shards of this shard's region.
    """
    w = cand_words.shape[0]
    x = cand_words.reshape(num_shards, w // num_shards)
    x = jax.lax.all_to_all(x, axis_names, split_axis=0, concat_axis=0,
                           tiled=False)
    return _or_reduce(x)


def _or_reduce(x: jax.Array) -> jax.Array:
    """Single-op bitwise-OR reduction over axis 0."""
    return jax.lax.reduce(x, jnp.uint32(0), jax.lax.bitwise_or, (0,))


def or_reduce_scatter_staged(cand_words: jax.Array,
                             axis_names: tuple[str, ...],
                             axis_sizes: tuple[int, ...]) -> jax.Array:
    """Multi-layer-crossbar delivery: per-axis exchange + OR between layers.

    Axis order must be most-significant-first in the flattened shard index
    (shard = ((pod*D)+data)*M + model), matching contiguous region ownership.
    """
    cur = cand_words
    for name, size in zip(axis_names, axis_sizes):
        w = cur.shape[0]
        x = cur.reshape(size, w // size)
        x = jax.lax.all_to_all(x, name, split_axis=0, concat_axis=0,
                               tiled=False)
        cur = _or_reduce(x)
    return cur


# ---------------------------------------------------------------------------
# Queue dispatch: capacity-bounded vertex-ID all-to-all (literal FIFOs).
# ---------------------------------------------------------------------------

def queue_dispatch(nbr_ids: jax.Array, axis_names: tuple[str, ...],
                   num_shards: int, verts_per_shard: int, capacity: int):
    """Route vertex IDs to their owning shards with per-destination capacity.

    nbr_ids: int32[B] global reindexed vertex IDs, -1 = empty slot.
    Returns (received int32[Q*capacity] global IDs with -1 pad,
             leftover int32[B] IDs that overflowed this round's FIFOs).
    """
    b = nbr_ids.shape[0]
    owner = jnp.where(nbr_ids >= 0, nbr_ids // verts_per_shard, num_shards)
    order = jnp.argsort(owner)                      # stable: invalid last
    ids_sorted = nbr_ids[order]
    owner_sorted = owner[order]
    group_start = jnp.searchsorted(owner_sorted,
                                   jnp.arange(num_shards + 1), side="left")
    rank = jnp.arange(b, dtype=jnp.int32) - group_start[
        jnp.minimum(owner_sorted, num_shards)].astype(jnp.int32)
    fits = (owner_sorted < num_shards) & (rank < capacity)
    slot = jnp.where(fits, owner_sorted * capacity + rank, num_shards * capacity)
    send = jnp.full((num_shards * capacity + 1,), -1, jnp.int32)
    send = send.at[slot].set(jnp.where(fits, ids_sorted, -1))[:-1]
    recv = jax.lax.all_to_all(send.reshape(num_shards, capacity), axis_names,
                              split_axis=0, concat_axis=0, tiled=False)
    leftover = jnp.where(fits | (owner_sorted >= num_shards), -1, ids_sorted)
    return recv.reshape(-1), leftover


def received_to_local_bits(recv_ids: jax.Array, shard_index: jax.Array,
                           verts_per_shard: int) -> jax.Array:
    """Convert received global IDs into this shard's local candidate bitmap."""
    local = recv_ids - shard_index * verts_per_shard
    local = jnp.where(recv_ids >= 0, local, -1)
    return bitmap.from_indices_dense(local, verts_per_shard)
