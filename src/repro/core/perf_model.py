"""Analytic performance model (paper §V, Eq. 1-7).

Reproduces Fig. 7 exactly with the paper's U280 constants and re-parameterizes
the same model for TPU v5e (the target of this port) so the roofline section
can compare the model against the compiled-HLO roofline.

Also implements the multi-layer crossbar resource model (Eq. 7) and the
FIFO-count comparison of §IV-D (full vs k-layer crossbar).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class PerfModelConfig:
    """Paper's symbols. Defaults = paper's Fig. 7 setting."""
    s_v_bits: int = 32            # S_v: storage size of a vertex
    freq_hz: float = 100e6        # F: PE clock
    bw_max: float = 13.27e9       # BW_MAX: single-PC physical bandwidth (B/s)


def axi_data_width_bits(n_pe: int, s_v_bits: int = 32) -> int:
    """Eq. 1: DW = 2 * N_pe * S_v (double-pump BRAM: 2 ops/cycle/PE)."""
    return 2 * n_pe * s_v_bits


def pc_bandwidth(n_pe: int, cfg: PerfModelConfig) -> float:
    """Eq. 2: min(DW*F, BW_MAX) in bytes/s."""
    dw_bytes = axi_data_width_bits(n_pe, cfg.s_v_bits) / 8
    return min(dw_bytes * cfg.freq_hz, cfg.bw_max)


def p_nl(n_pe: int, len_nl: float, cfg: PerfModelConfig) -> float:
    """Eq. 3: fraction of PC bandwidth spent on neighbor lists."""
    dw = axi_data_width_bits(n_pe, cfg.s_v_bits)
    return (len_nl * cfg.s_v_bits) / (dw + len_nl * cfg.s_v_bits)


def perf_pg(n_pe: int, len_nl: float, cfg: PerfModelConfig) -> float:
    """Eq. 5: theoretical TEPS of a single processing group."""
    bw_nl = pc_bandwidth(n_pe, cfg) * p_nl(n_pe, len_nl, cfg)
    return bw_nl / (cfg.s_v_bits / 8)


def perf_total(n_pe: int, n_pc: int, len_nl: float,
               cfg: PerfModelConfig | None = None) -> float:
    """Eq. 6: Perf = Perf_pg * N_pc (TEPS)."""
    cfg = cfg or PerfModelConfig()
    return perf_pg(n_pe, len_nl, cfg) * n_pc


def fig7_curves(pe_counts=(1, 2, 4, 8, 16, 32, 64, 128),
                len_nls=(1, 2, 4, 8, 16, 32, 64, 128),
                cfg: PerfModelConfig | None = None):
    """Fig. 7 data: GTEPS per (len_nl curve, n_pe point), single PC."""
    cfg = cfg or PerfModelConfig()
    return {ln: [perf_total(p, 1, ln, cfg) / 1e9 for p in pe_counts]
            for ln in len_nls}


def break_point_pes(cfg: PerfModelConfig | None = None) -> int:
    """Largest power-of-two #PEs whose AXI width still fits the PC's
    physical bandwidth (2*N_pe*S_v*F <= BW_MAX) -- the Fig. 7 peak."""
    cfg = cfg or PerfModelConfig()
    n = cfg.bw_max / (2 * (cfg.s_v_bits / 8) * cfg.freq_hz)
    return 2 ** math.floor(math.log2(n))


# ---------------------------------------------------------------------------
# Crossbar resource model (§IV-D + Eq. 7)
# ---------------------------------------------------------------------------

def full_crossbar_fifos(n: int) -> int:
    return n * n


def multilayer_crossbar_fifos(factors: tuple[int, ...]) -> int:
    """Sum over layers of (N/C_i) * C_i^2 FIFOs, N = prod(C_i)."""
    n = math.prod(factors)
    return sum((n // c) * c * c for c in factors)


def crossbar_lut_constraint(n_pe: int, k: int, r_fifo: float, r_pe: float,
                            r_limit: float) -> bool:
    """Eq. 7: k * N^(1/k + 1) * R_FIFO + N * R_PE < R_limit."""
    return (k * n_pe ** (1.0 / k + 1.0) * r_fifo + n_pe * r_pe) < r_limit


# ---------------------------------------------------------------------------
# TPU v5e re-parameterization (hardware-adaptation of §V)
# ---------------------------------------------------------------------------

V5E = dict(hbm_bw=819e9, ici_bw=50e9, peak_bf16=197e12, chips_per_pod=256)


def tpu_model_teps(n_chips: int, len_nl: float, s_v_bits: int = 32,
                   visit_eff: float = 1.0) -> float:
    """The paper's Eq. 6 with PC->chip: TEPS if each chip streams neighbor
    lists at HBM bandwidth.  ``visit_eff`` discounts for edges inspected more
    than once across modes (hybrid ~= 1)."""
    bw_nl = V5E["hbm_bw"] * (len_nl * s_v_bits) / (64 + len_nl * s_v_bits)
    # 64-bit overhead per vertex: offset-pair read, the DW analogue.
    return n_chips * bw_nl / (s_v_bits / 8) * visit_eff
