"""Distributed BFS over a device mesh (paper §IV, scaled to pods).

One mesh device == one Processing Group bound to one memory channel; each
device hosts ``k`` Processing Elements (k = shards per device), every PE
owning one contiguous (reindexed) vertex interval — level array +
visited/frontier bitmap shards live in the device's HBM, neighbor lists
stream from that HBM only (the paper's locality rule; see DESIGN.md §2).
``k`` is the paper's second scaling direction (PEs per PC, Fig. 10).

Iteration structure (python-driven, each step a jitted shard_map program):

  push:  P1 compact local frontiers (per PE) -> P2 expand local CSR
         out-lists -> DISPATCH candidates to owners (crossbar analogue)
         -> P3 receiver filters visited, updates bitmaps + levels.
  pull:  all-gather the (bit-packed) current frontier
         -> P1 compact local unvisited -> P2 expand local CSC in-lists,
         test parent frontier bits -> P3 local update (no dispatch).

Direction choice per iteration uses globally psum'd frontier statistics
(the Scheduler broadcasting its decision to all PEs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core import bitmap
from repro.core.bfs_local import (INF, SV_MF, SV_MU, SV_NF, SV_NU,
                                  SV_OVERFLOW, SV_TOTAL, compact_indices,
                                  expand_edges, validate_roots)
from repro.core.dispatcher import (or_reduce_scatter_flat,
                                   or_reduce_scatter_staged, queue_dispatch,
                                   received_to_local_bits)
from repro.core.partition import PartitionedGraph, reindex, unreindex
from repro.core.scheduler import (PULL, PUSH, SchedulerConfig, choose_mode,
                                  choose_mode_host)
from repro.core.vertex_program import BFS, VertexProgram


@dataclasses.dataclass
class DistConfig:
    dispatch: str = "bitmap"      # "bitmap" | "queue"
    crossbar: str = "staged"      # "staged" (multi-layer) | "flat" (full)
    edge_budget: int = 1 << 15    # per-shard expansion budget (auto-grows)
    queue_capacity: int = 1 << 12  # per-destination FIFO depth (queue mode)
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    # Batched pull through the row-tiled fused propagate kernel
    # (kernels.ops.msbfs_propagate_msgs) instead of the jnp scatter-OR.
    # Pull only: the push candidates must cross the OR-reduce-scatter
    # crossbar BEFORE the visited filter, so its P3 cannot fuse into the
    # local scatter.  tile_rows=None tiles at the PE vertex interval
    # (verts_per_shard) — the partition the kernel's tiles mirror.
    use_pallas: bool = False
    tile_rows: int | None = None


class DistributedBFS:
    """Vertex-program engine over `mesh`: Q = d*k shards, k PEs per device.

    The batched path is program-parameterized (``run_program_batch``):
    the default ``program`` (BFS unless overridden at construction) keeps
    ``run_batch`` protocol-uniform, so one ``DistributedBFS(pg, mesh,
    program=CC)`` serves CC through the same ``BFSEngine`` surface.
    """

    def __init__(self, pg: PartitionedGraph, mesh: jax.sharding.Mesh,
                 axis_names: tuple[str, ...] | None = None,
                 cfg: DistConfig | None = None,
                 program: VertexProgram = BFS):
        self.pg = pg
        self.program = program
        self.mesh = mesh
        self.axes = tuple(axis_names or mesh.axis_names)
        self.axis_sizes = tuple(mesh.shape[a] for a in self.axes)
        self.cfg = cfg or DistConfig()
        q = pg.num_shards
        d = int(np.prod(self.axis_sizes))
        assert q % d == 0, f"shards {q} not a multiple of mesh size {d}"
        self.d = d
        self.k = q // d          # shards (PEs) per device (PC)
        self.q = q
        self.vl = pg.verts_per_shard          # local vertices per shard
        self.wl = self.vl // bitmap.WORD_BITS  # local bitmap words
        self.n_pad = pg.num_vertices_padded
        spec = NamedSharding(mesh, P(self.axes))
        put = lambda x: jax.device_put(jnp.asarray(x), spec)
        # Shard-stacked graph arrays: leading axis Q splits across devices.
        self.out_indptr = put(pg.out_indptr.astype(np.int32))
        self.out_indices = put(pg.out_indices)
        self.in_indptr = put(pg.in_indptr.astype(np.int32))
        self.in_indices = put(pg.in_indices)
        # stored per-shard degrees: the per-level scheduler stats would
        # otherwise re-derive them with jnp.diff every single iteration
        out_deg_r = np.diff(pg.out_indptr, axis=1)
        self._out_deg_dev = put(out_deg_r.astype(np.int32))
        self._in_deg_dev = put(np.diff(pg.in_indptr, axis=1).astype(np.int32))
        # original-order degrees for the engine protocol (per-wave TEPS)
        gidx = np.arange(self.n_pad)
        orig = (unreindex(gidx, q, self.vl) if pg.scheme == "hash" else gidx)
        deg = np.zeros(pg.num_vertices, np.int64)
        ok = orig < pg.num_vertices
        deg[orig[ok]] = out_deg_r.reshape(-1)[ok]
        self._out_deg_np = deg
        self._steps = {}

    @property
    def num_vertices(self) -> int:
        """|V| served (the :class:`repro.core.BFSEngine` protocol)."""
        return int(self.pg.num_vertices)

    @property
    def out_deg(self) -> np.ndarray | None:
        """Original-order out-degrees [n] (engine protocol), or None for
        ``abstract()`` spec-only engines with no materialized graph."""
        return self._out_deg_np

    @classmethod
    def abstract(cls, mesh: jax.sharding.Mesh, num_vertices: int,
                 axis_names: tuple[str, ...] | None = None,
                 cfg: DistConfig | None = None, align: int = 32,
                 pes_per_device: int = 1):
        """Spec-only engine for the multi-pod dry-run: no graph arrays are
        materialized; the jitted step programs can be .lower()ed against
        ShapeDtypeStruct inputs (see abstract_inputs)."""
        self = cls.__new__(cls)
        self.pg = None
        self.program = BFS
        self._out_deg_np = None
        self.mesh = mesh
        self.axes = tuple(axis_names or mesh.axis_names)
        self.axis_sizes = tuple(mesh.shape[a] for a in self.axes)
        self.cfg = cfg or DistConfig()
        d = int(np.prod(self.axis_sizes))
        q = d * pes_per_device
        self.d = d
        self.k = pes_per_device
        self.q = q
        vl = (num_vertices + q - 1) // q
        vl = ((vl + align - 1) // align) * align
        self.vl = vl
        self.wl = vl // bitmap.WORD_BITS
        self.n_pad = q * vl
        self._steps = {}
        return self

    def abstract_inputs(self, avg_degree: float = 16.0,
                        pad_multiple: int = 128) -> dict:
        """ShapeDtypeStruct stand-ins for one BFS step's inputs."""
        e = int(self.vl * avg_degree)
        e = max(((e + pad_multiple - 1) // pad_multiple) * pad_multiple,
                pad_multiple)
        sds = jax.ShapeDtypeStruct
        return dict(
            frontier=sds((self.q, self.wl), jnp.uint32),
            visited=sds((self.q, self.wl), jnp.uint32),
            level=sds((self.q, self.vl), jnp.int32),
            lvl=sds((), jnp.int32),
            indptr=sds((self.q, self.vl + 1), jnp.int32),
            indices=sds((self.q, e), jnp.int32),
        )

    # -- sharded state helpers -------------------------------------------
    def _sharding(self):
        return NamedSharding(self.mesh, P(self.axes))

    def init_state(self, root_reindexed: int):
        s = self._sharding()
        q, vl = self.q, self.vl
        frontier = np.zeros((q, self.wl), np.uint32)
        shard, local = root_reindexed // vl, root_reindexed % vl
        frontier[shard, local // 32] = np.uint32(1) << (local % 32)
        level = np.full((q, vl), int(INF), np.int32)
        level[shard, local] = 0
        return (jax.device_put(jnp.asarray(frontier), s),
                jax.device_put(jnp.asarray(frontier), s),   # visited
                jax.device_put(jnp.asarray(level), s))

    # -- jitted sharded programs -----------------------------------------
    # Every shard_map block is [k, ...]: k PE rows on this device.
    def _specs(self):
        return P(self.axes)

    def _unpack_rows(self, words):
        return jax.vmap(lambda w: bitmap.unpack(w, self.vl))(words)

    def _stats_fn(self):
        axes = self.axes

        def stats(frontier, visited, out_indptr, in_indptr):
            fmask = self._unpack_rows(frontier)            # [k, vl]
            umask = ~self._unpack_rows(visited)
            odeg = jnp.diff(out_indptr, axis=1)
            ideg = jnp.diff(in_indptr, axis=1)
            n_f = jax.lax.psum(jnp.sum(fmask, dtype=jnp.int32), axes)
            m_f = jax.lax.psum(jnp.sum(jnp.where(fmask, odeg, 0),
                                       dtype=jnp.int32), axes)
            m_u = jax.lax.psum(jnp.sum(jnp.where(umask, ideg, 0),
                                       dtype=jnp.int32), axes)
            n_u = jax.lax.psum(jnp.sum(umask, dtype=jnp.int32), axes)
            return n_f, m_f, m_u, n_u

        sp = self._specs()
        return jax.jit(shard_map(
            stats, mesh=self.mesh,
            in_specs=(sp, sp, sp, sp),
            out_specs=(P(), P(), P(), P())))

    def _push_fn(self, budget: int):
        cfg, axes, sizes = self.cfg, self.axes, self.axis_sizes
        vl, wl, n_pad = self.vl, self.wl, self.n_pad
        d, k = self.d, self.k

        def push(frontier, visited, level, lvl, out_indptr, out_indices):
            fmask = self._unpack_rows(frontier)             # [k, vl]
            active = jax.vmap(lambda m: compact_indices(m, vl)[0])(fmask)
            _, nbr, valid, total = jax.vmap(
                lambda a, ip, ix: expand_edges(a, ip, ix, budget))(
                active, out_indptr, out_indices)            # nbr [k, budget]
            overflow = jax.lax.psum(
                jnp.any(total > budget).astype(jnp.int32), axes)
            nbr_flat = nbr.reshape(-1)
            if cfg.dispatch == "bitmap":
                cand_global = bitmap.from_indices_dense(nbr_flat, n_pad)
                if cfg.crossbar == "staged":
                    cand_dev = or_reduce_scatter_staged(cand_global, axes,
                                                        sizes)
                else:
                    cand_dev = or_reduce_scatter_flat(cand_global, axes, d)
                cand_local = cand_dev.reshape(k, wl)
                leftover = jnp.full((k, budget), -1, jnp.int32)
            else:
                sidx = _flat_axis_index(axes)
                recv, leftover_f = queue_dispatch(nbr_flat, axes, d, k * vl,
                                                  cfg.queue_capacity)
                cand_local = received_to_local_bits(
                    recv, sidx, k * vl).reshape(k, wl)
                leftover = leftover_f.reshape(k, budget)
            new = cand_local & ~visited
            v2 = visited | new
            new_mask = self._unpack_rows(new)
            lev2 = jnp.where(new_mask, lvl + 1, level)
            pending = jax.lax.psum(jnp.sum(leftover >= 0, dtype=jnp.int32),
                                   axes)
            return (new, v2, lev2, overflow,
                    jax.lax.psum(jnp.sum(total), axes), pending, leftover)

        sp = self._specs()
        return jax.jit(shard_map(
            push, mesh=self.mesh,
            in_specs=(sp, sp, sp, P(), sp, sp),
            out_specs=(sp, sp, sp, P(), P(), P(), sp)))

    def _queue_drain_fn(self):
        """Retry round for queue-mode overflow: dispatch leftover IDs."""
        cfg, axes = self.cfg, self.axes
        vl, wl, d, k = self.vl, self.wl, self.d, self.k

        def drain(frontier, visited, level, lvl, leftover):
            sidx = _flat_axis_index(axes)
            recv, left2 = queue_dispatch(leftover.reshape(-1), axes, d,
                                         k * vl, cfg.queue_capacity)
            cand_local = received_to_local_bits(
                recv, sidx, k * vl).reshape(k, wl)
            new = cand_local & ~visited
            v2 = visited | new
            new_mask = self._unpack_rows(new)
            lev2 = jnp.where(new_mask, lvl + 1, level)
            pending = jax.lax.psum(jnp.sum(left2 >= 0, dtype=jnp.int32),
                                   axes)
            return (frontier | new, v2, lev2, pending,
                    left2.reshape(leftover.shape))

        sp = self._specs()
        return jax.jit(shard_map(
            drain, mesh=self.mesh,
            in_specs=(sp, sp, sp, P(), sp),
            out_specs=(sp, sp, sp, P(), sp)))

    def _pull_fn(self, budget: int):
        axes, vl = self.axes, self.vl

        def pull(frontier, visited, level, lvl, in_indptr, in_indices):
            # all-gather the packed frontier (W bits total = |V|): the pull
            # mode's "read current_frontier of remote parents".
            f_global = jax.lax.all_gather(frontier, axes,
                                          tiled=True).reshape(-1)
            umask = ~self._unpack_rows(visited)
            unvisited = jax.vmap(lambda m: compact_indices(m, vl)[0])(umask)
            child, parent, valid, total = jax.vmap(
                lambda a, ip, ix: expand_edges(a, ip, ix, budget))(
                unvisited, in_indptr, in_indices)
            overflow = jax.lax.psum(
                jnp.any(total > budget).astype(jnp.int32), axes)
            hit = bitmap.test_bits(
                f_global, jnp.maximum(parent.reshape(-1), 0)
            ).reshape(parent.shape) & valid
            cand = jax.vmap(
                lambda h, c: bitmap.from_indices_dense(
                    jnp.where(h, c, -1), vl))(hit, child)
            new = cand & ~visited
            v2 = visited | new
            new_mask = self._unpack_rows(new)
            lev2 = jnp.where(new_mask, lvl + 1, level)
            return (new, v2, lev2, overflow,
                    jax.lax.psum(jnp.sum(total), axes))

        sp = self._specs()
        return jax.jit(shard_map(
            pull, mesh=self.mesh,
            in_specs=(sp, sp, sp, P(), sp, sp),
            out_specs=(sp, sp, sp, P(), P())))

    # -- batched multi-source steps (one bit-plane per source) ------------
    # State: frontier/seen uint32[q, vl, nwb] (source-mask words per local
    # vertex), level int32[q, vl, B].  Dispatch is always bitmap-mode: the
    # crossbar payload is the packed source-mask plane set and combining
    # stays a bitwise OR, so the same OR-reduce-scatter delivers a whole
    # batch per exchange (the "more concurrent work per memory pass" lever).
    #
    # Packed-word invariant: P2 gathers the packed source-mask WORDS of
    # each budgeted edge's endpoint and scatter-ORs them into the candidate
    # plane words (bitmap._scatter_or_rows — the jnp twin of the Pallas
    # msbfs_propagate kernel); plane state never unpacks between P1 and the
    # level update.  Each step also returns the NEXT level's scheduler
    # stats stacked into one replicated int32[7], so run_batch performs a
    # single blocking device->host transfer per level.

    def _ms_statvec_b(self, new, s2, odeg, ideg, total, overflow, nb: int):
        axes = self.axes
        pmask = bitmap.plane_mask(nb)
        any_f = bitmap.any_rows(new)                   # [k, vl]
        un_any = bitmap.any_rows(~s2 & pmask)
        n_f = jax.lax.psum(jnp.sum(any_f, dtype=jnp.int32), axes)
        m_f = jax.lax.psum(jnp.sum(jnp.where(any_f, odeg, 0),
                                   dtype=jnp.int32), axes)
        m_u = jax.lax.psum(jnp.sum(jnp.where(un_any, ideg, 0),
                                   dtype=jnp.int32), axes)
        n_u = jax.lax.psum(jnp.sum(un_any, dtype=jnp.int32), axes)
        cnt = jax.lax.psum(bitmap.popcount(new), axes)
        return jnp.stack([n_f, m_f, m_u, n_u,
                          jnp.asarray(total, jnp.int32),
                          jnp.asarray(overflow, jnp.int32), cnt])

    def _stats_batch_fn(self, nb: int):
        def stats_b(frontier, seen, out_deg, in_deg):
            return self._ms_statvec_b(frontier, seen, out_deg, in_deg,
                                      0, 0, nb)

        sp = self._specs()
        return jax.jit(shard_map(
            stats_b, mesh=self.mesh,
            in_specs=(sp, sp, sp, sp),
            out_specs=P()))

    def _push_batch_fn(self, budget: int, nb: int,
                       program: VertexProgram = BFS):
        cfg, axes, sizes = self.cfg, self.axes, self.axis_sizes
        vl, n_pad = self.vl, self.n_pad
        d, k = self.d, self.k
        nwb = bitmap.num_words(nb)

        def push_b(frontier, seen, level, lvl, out_indptr, out_indices,
                   out_deg, in_deg):
            any_f = bitmap.any_rows(frontier)              # [k, vl]
            active = jax.vmap(lambda m: compact_indices(m, vl)[0])(any_f)
            src, nbr, valid, total = jax.vmap(
                lambda a, ip, ix: expand_edges(a, ip, ix, budget))(
                active, out_indptr, out_indices)           # [k, budget]
            overflow = jax.lax.psum(
                jnp.any(total > budget).astype(jnp.int32), axes)
            # P2->P3 on packed words: gather each edge's source-mask word,
            # scatter-OR into the GLOBAL candidate planes (the crossbar
            # payload), no bool intermediates
            msg = jax.vmap(lambda fw, s: fw[jnp.maximum(s, 0)])(
                frontier, src)                             # [k, budget, nwb]
            tgt = jnp.where(valid, nbr, n_pad).reshape(-1)
            cand_w = bitmap._scatter_or_rows(
                jnp.zeros((n_pad, nwb), jnp.uint32), tgt,
                msg.reshape(-1, nwb)).reshape(-1)          # [n_pad * nwb]
            if cfg.crossbar == "staged":
                cand_dev = or_reduce_scatter_staged(cand_w, axes, sizes)
            else:
                cand_dev = or_reduce_scatter_flat(cand_w, axes, d)
            cand_local = cand_dev.reshape(k, vl, nwb)
            new = cand_local & ~seen
            s2 = seen | new
            new_mask = bitmap.unpack_rows(new, nb)         # program apply
            lev2 = program.commit(level, new_mask, lvl)
            statvec = self._ms_statvec_b(
                new, s2, out_deg, in_deg,
                jax.lax.psum(jnp.sum(total), axes), overflow, nb)
            return new, s2, lev2, statvec

        sp = self._specs()
        return jax.jit(shard_map(
            push_b, mesh=self.mesh,
            in_specs=(sp, sp, sp, P(), sp, sp, sp, sp),
            out_specs=(sp, sp, sp, P())))

    def _pull_batch_fn(self, budget: int, nb: int,
                       program: VertexProgram = BFS):
        axes, vl, nwb = self.axes, self.vl, bitmap.num_words(nb)
        cfg, k = self.cfg, self.k

        def pull_b(frontier, seen, level, lvl, in_indptr, in_indices,
                   out_deg, in_deg):
            # all-gather the packed source planes of every vertex: the pull
            # mode's "read current_frontier of remote parents", batched.
            f_global = jax.lax.all_gather(frontier, axes,
                                          tiled=True).reshape(-1, nwb)
            pmask = bitmap.plane_mask(nb)
            un_any = bitmap.any_rows(~seen & pmask)
            unvisited = jax.vmap(lambda m: compact_indices(m, vl)[0])(un_any)
            child, parent, valid, total = jax.vmap(
                lambda a, ip, ix: expand_edges(a, ip, ix, budget))(
                unvisited, in_indptr, in_indices)
            overflow = jax.lax.psum(
                jnp.any(total > budget).astype(jnp.int32), axes)
            # packed P2->P3: parents' plane words combine into each PE's
            # local candidate words — the gather reads the all-gathered
            # GLOBAL frontier while the scatter stays shard-local, which
            # is exactly the msgs-form fused kernel's contract
            msg = f_global[jnp.maximum(parent, 0)]         # [k, budget, nwb]
            if cfg.use_pallas:
                # row-tiled fused propagate over the k PE rows stacked
                # flat: with tile_rows = vl each kernel tile IS one PE's
                # vertex interval (the paper's PC-feeds-its-own-partition
                # rule), and P3 + the discovery popcount fuse in-kernel
                from repro.kernels import ops as kops
                offs = (jnp.arange(k, dtype=jnp.int32) * vl)[:, None]
                new_f, s2_f, _ = kops.msbfs_propagate_msgs(
                    seen.reshape(k * vl, nwb), msg.reshape(-1, nwb),
                    jnp.where(valid, child + offs, -1).reshape(-1),
                    valid.reshape(-1), tile_rows=cfg.tile_rows or vl,
                    op=program.combine)
                new = new_f.reshape(k, vl, nwb)
                s2 = s2_f.reshape(k, vl, nwb)
            else:
                cand_w = jax.vmap(
                    lambda t, m: bitmap._scatter_or_rows(
                        jnp.zeros((vl, nwb), jnp.uint32), t, m))(
                    jnp.where(valid, child, vl), msg)
                new = cand_w & ~seen
                s2 = seen | new
            new_mask = bitmap.unpack_rows(new, nb)         # program apply
            lev2 = program.commit(level, new_mask, lvl)
            statvec = self._ms_statvec_b(
                new, s2, out_deg, in_deg,
                jax.lax.psum(jnp.sum(total), axes), overflow, nb)
            return new, s2, lev2, statvec

        sp = self._specs()
        # pallas_call has no shard_map replication rule — per-shard outputs
        # here are all explicitly sharded or psum'd, so skip the check
        return jax.jit(shard_map(
            pull_b, mesh=self.mesh,
            in_specs=(sp, sp, sp, P(), sp, sp, sp, sp),
            out_specs=(sp, sp, sp, P()),
            check_vma=False if cfg.use_pallas else None))

    def _get(self, kind: str, budget: int, nb: int = 0,
             program: VertexProgram = BFS):
        key = (kind, budget, nb, program.name)
        if key not in self._steps:
            if kind == "push":
                self._steps[key] = self._push_fn(budget)
            elif kind == "pull":
                self._steps[key] = self._pull_fn(budget)
            elif kind == "stats":
                self._steps[key] = self._stats_fn()
            elif kind == "drain":
                self._steps[key] = self._queue_drain_fn()
            elif kind == "push_b":
                self._steps[key] = self._push_batch_fn(budget, nb, program)
            elif kind == "pull_b":
                self._steps[key] = self._pull_batch_fn(budget, nb, program)
            elif kind == "stats_b":
                self._steps[key] = self._stats_batch_fn(nb)
        return self._steps[key]

    def init_state_batch(self, roots_reindexed: np.ndarray):
        s = self._sharding()
        q, vl = self.q, self.vl
        b = int(roots_reindexed.size)
        nwb = bitmap.num_words(b)
        frontier = np.zeros((q, vl, nwb), np.uint32)
        level = np.full((q, vl, b), int(INF), np.int32)
        for i, r in enumerate(np.asarray(roots_reindexed)):
            shard, local = int(r) // vl, int(r) % vl
            frontier[shard, local, i // 32] |= np.uint32(1) << (i % 32)
            level[shard, local, i] = 0
        return (jax.device_put(jnp.asarray(frontier), s),
                jax.device_put(jnp.asarray(frontier), s),   # seen
                jax.device_put(jnp.asarray(level), s))

    # -- driver -----------------------------------------------------------
    def run(self, root: int, max_iters: int | None = None):
        """BFS from original-ID ``root``; returns level int32[num_vertices]."""
        pg, cfg = self.pg, self.cfg
        if pg.scheme == "hash":
            root_r = int(reindex(np.asarray(root), pg.num_shards,
                                 pg.verts_per_shard))
        else:
            root_r = root
        frontier, visited, level = self.init_state(root_r)
        stats = self._get("stats", 0)
        budget = cfg.edge_budget
        lvl = jnp.int32(0)
        mode = jnp.int32(PUSH)
        iters = 0
        inspected = 0
        push_iters = pull_iters = 0
        max_iters = max_iters or self.n_pad
        while iters < max_iters:
            n_f, m_f, m_u, n_u = stats(frontier, visited, self.out_indptr,
                                       self.in_indptr)
            if int(n_f) == 0:
                break
            mode = choose_mode(cfg.scheduler, mode, n_f, m_f, m_u,
                               pg.num_vertices, n_u)
            is_push = int(mode) == PUSH
            need = int(m_f) if is_push else int(m_u)
            while budget * self.k < need:
                budget *= 2
            while True:
                if is_push:
                    out = self._get("push", budget)(
                        frontier, visited, level, lvl,
                        self.out_indptr, self.out_indices)
                    frontier2, visited2, level2, overflow, total = out[:5]
                    pending, leftover = out[5], out[6]
                else:
                    (frontier2, visited2, level2, overflow,
                     total) = self._get("pull", budget)(
                        frontier, visited, level, lvl,
                        self.in_indptr, self.in_indices)
                    pending = 0
                if int(overflow) == 0:
                    break
                budget *= 2            # HBM-reader queue deepening, retry
            # queue-mode FIFO overflow: extra dispatch rounds (same level).
            while int(pending) > 0:
                drain = self._get("drain", 0)
                frontier2, visited2, level2, pending, leftover = drain(
                    frontier2, visited2, level2, lvl, leftover)
            frontier, visited, level = frontier2, visited2, level2
            inspected += int(total)
            if is_push:
                push_iters += 1
            else:
                pull_iters += 1
            lvl = lvl + 1
            iters += 1
        # un-reindex levels back to original vertex order
        lev = np.asarray(level).reshape(-1)           # [q*vl] reindexed
        g = np.arange(self.n_pad)
        orig = (unreindex(g, self.q, self.vl) if pg.scheme == "hash" else g)
        out = np.full(pg.num_vertices, int(INF), np.int64)
        ok = orig < pg.num_vertices
        out[orig[ok]] = lev[ok]
        self.last_stats = dict(iterations=iters, edges_inspected=inspected,
                               push_iters=push_iters, pull_iters=pull_iters)
        return out

    def run_batch(self, roots, max_iters: int | None = None):
        """Batched vertex program from original-ID ``roots`` (the engine's
        construction-time ``program``; BFS by default).

        Returns value rows int32[B, num_vertices].  All B planes run
        level-synchronously over the same sharded graph; every CSR/CSC
        edge read and every crossbar exchange carries the whole batch's
        plane masks (bitmap dispatch only — FIFO queues carry scalar
        vertex IDs and would lose the sharing).
        """
        return self.run_program_batch(self.program, roots, max_iters)

    def run_program_batch(self, program: VertexProgram, roots,
                          max_iters: int | None = None):
        """One-sync-per-level batched driver, parameterized by program.

        The SHARED distributed entry: root validation happens here, once,
        for every algorithm.
        """
        pg, cfg = self.pg, self.cfg
        if cfg.dispatch != "bitmap":
            raise NotImplementedError(
                "run_batch supports bitmap dispatch only: FIFO queues carry "
                "scalar vertex IDs, not per-source masks")
        if program.combine != "or":
            raise NotImplementedError(
                "the distributed crossbar is an OR-reduce-scatter; "
                f"program {program.name!r} wants combine={program.combine!r}")
        # validate BEFORE the int64 cast (a float root must error, not
        # truncate); duplicates are allowed — one plane slot each
        roots = validate_roots(np.asarray(roots),
                               pg.num_vertices).astype(np.int64)
        b = int(roots.size)
        if pg.scheme == "hash":
            roots_r = reindex(roots, pg.num_shards, pg.verts_per_shard)
        else:
            roots_r = roots
        frontier, seen, level = self.init_state_batch(roots_r)
        # one-sync-per-level driver: every step returns the next level's
        # scheduler stats as ONE replicated int32[7]; the loop's only
        # blocking device->host transfer per level is that vector.
        sv = np.asarray(self._get("stats_b", 0, b)(
            frontier, seen, self._out_deg_dev, self._in_deg_dev))
        budget = cfg.edge_budget
        mode = PUSH
        iters = 0
        inspected = 0
        push_iters = pull_iters = 0
        max_iters = max_iters or self.n_pad
        while iters < max_iters and not program.done(sv):
            mode = choose_mode_host(cfg.scheduler, mode, int(sv[SV_NF]),
                                    int(sv[SV_MF]), int(sv[SV_MU]),
                                    pg.num_vertices, int(sv[SV_NU]))
            is_push = mode == PUSH
            need = int(sv[SV_MF]) if is_push else int(sv[SV_MU])
            while budget * self.k < need:
                budget *= 2
            while True:
                kind = "push_b" if is_push else "pull_b"
                arrays = ((self.out_indptr, self.out_indices) if is_push
                          else (self.in_indptr, self.in_indices))
                (frontier2, seen2, level2, statvec) = self._get(
                    kind, budget, b, program)(
                    frontier, seen, level, np.int32(iters), *arrays,
                    self._out_deg_dev, self._in_deg_dev)
                sv = np.asarray(statvec)
                if int(sv[SV_OVERFLOW]) == 0:
                    break
                budget *= 2            # HBM-reader queue deepening, retry
            frontier, seen, level = frontier2, seen2, level2
            inspected += int(sv[SV_TOTAL])
            if is_push:
                push_iters += 1
            else:
                pull_iters += 1
            iters += 1
        lev = np.asarray(level).reshape(-1, b)        # [q*vl, B] reindexed
        g = np.arange(self.n_pad)
        orig = (unreindex(g, self.q, self.vl) if pg.scheme == "hash" else g)
        out = np.full((b, pg.num_vertices), int(INF), np.int64)
        ok = orig < pg.num_vertices
        out[:, orig[ok]] = lev[ok].T
        self.last_stats = dict(iterations=iters, edges_inspected=inspected,
                               push_iters=push_iters, pull_iters=pull_iters,
                               batch=b, algo=program.name)
        return out


def _flat_axis_index(axes: tuple[str, ...]) -> jax.Array:
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx
