"""Direction-optimizing scheduler (paper §IV-B "Scheduler").

ScalaBFS switches every PE between push (beginning/ending iterations) and
pull (mid-term iterations).  We implement two policies:

* ``paper``  — the paper's coarse policy: push while the frontier is small,
  pull during mid-term, push again at the end.  Operationalized via the same
  quantities the hardware Scheduler observes (frontier size / unvisited
  count) with hysteresis.
* ``beamer`` — Beamer et al. direction-optimizing heuristic [33]:
  push→pull when m_f > m_u / alpha, pull→push when n_f < |V| / beta.
  This is the default (the paper cites [33] as the basis of its hybrid mode).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

PUSH = 0
PULL = 1


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "beamer"   # "beamer" | "paper" | "push" | "pull"
    alpha: float = 14.0
    beta: float = 24.0


def choose_mode_host(cfg: SchedulerConfig, prev_mode: int, n_f: int,
                     m_f: int, m_u: int, n: int, n_unvisited: int) -> int:
    """Pure-python :func:`choose_mode` for the one-sync-per-level drivers.

    The packed drivers fetch one stacked stats vector per level and decide
    the direction on the host — routing the already-fetched scalars back
    through the jnp version would re-enter the device for a trivial
    comparison.  Must stay semantically identical to :func:`choose_mode`.
    """
    if cfg.policy == "push":
        return PUSH
    if cfg.policy == "pull":
        return PULL
    if cfg.policy == "paper":
        grow = n_f * 20 > n
        ending = n_unvisited * 20 < n
        return PULL if (grow and not ending) else PUSH
    if prev_mode == PUSH and m_f * cfg.alpha > m_u:
        return PULL
    if prev_mode == PULL and n_f * cfg.beta < n:
        return PUSH
    return int(prev_mode)


def choose_mode(cfg: SchedulerConfig, prev_mode, n_f, m_f, m_u, n, n_unvisited):
    """Return PUSH or PULL for the upcoming iteration (traced-friendly)."""
    if cfg.policy == "push":
        return jnp.int32(PUSH)
    if cfg.policy == "pull":
        return jnp.int32(PULL)
    if cfg.policy == "paper":
        # mid-term == a large fraction of vertices still unvisited but the
        # frontier has grown past a fixed fraction of |V|.
        grow = n_f * 20 > n
        ending = n_unvisited * 20 < n
        return jnp.where(grow & ~ending, jnp.int32(PULL), jnp.int32(PUSH))
    # beamer
    to_pull = (prev_mode == PUSH) & (m_f * cfg.alpha > m_u)
    to_push = (prev_mode == PULL) & (n_f * cfg.beta < n)
    mode = jnp.where(to_pull, jnp.int32(PULL),
                     jnp.where(to_push, jnp.int32(PUSH), prev_mode))
    return mode.astype(jnp.int32)
