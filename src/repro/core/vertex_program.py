"""Batched vertex-program engine: the MS-BFS pipeline, generalized.

ScalaBFS's arbiter/apply/scatter pipeline is not BFS-specific — GraphScale
and fpgagraphlib-style frameworks run BFS, CC, SSSP and PageRank through
one scatter/apply skeleton with per-algorithm apply logic.  This module is
the software analogue: the level loop, the packed uint32 plane exchange,
the hybrid push/pull scheduler and the one-sync-per-level statvec protocol
are shared machinery, parameterized by a :class:`VertexProgram` bundle:

* ``init(g, roots) -> (frontier, seen, value)`` — seed one bit-plane per
  root plus the per-vertex value array the program accumulates into.
* ``commit(value, new_mask, lvl) -> value`` — the per-level apply: how a
  newly-discovered (vertex, plane) updates the value array (BFS/CC set the
  level on first reach; SSSP takes a min-plus relaxation).
* ``combine`` — the plane merge op the fused propagate kernel and the
  distributed OR-reduce-scatter use ("or" for bit-planes; the kernel also
  implements "max" as the hook for payload planes — see
  ``kernels.msbfs_propagate``).
* ``done(statvec) -> bool`` — the convergence predicate, folded into the
  stacked per-level stats vector (no extra device round-trip).

The bit-plane trick transfers directly: a plane can carry a component seed
(CC) or a source id (SSSP hop-distance frontiers) just as well as a BFS
source, so every CSR/CSC edge read keeps serving the whole batch — the
software analogue of keeping all 32 HBM pseudo-channels busy.

Shipped instantiations: :class:`MultiSourceBFSRunner` (BFS, plus the
legacy bool-plane baseline), :class:`ConnectedComponentsRunner` (multi-
seed CC over the symmetrized graph) and :class:`SSSPRunner` (batched
unit-weight shortest-path hop distances).  All three inherit the packed-
word invariant (plane state never unpacks between P1 and the commit) and
the one-sync-per-level driver (``host_transfers == iterations + 2``).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap
from repro.core.bfs_local import (INF, SV_COUNT, SV_MF, SV_MU, SV_NF,
                                  SV_NU, SV_OVERFLOW, SV_TOTAL, LocalGraph,
                                  compact_indices, count_traversed_edges,
                                  expand_edges, validate_roots)
from repro.core.scheduler import (PUSH, SchedulerConfig, choose_mode,
                                  choose_mode_host)


# ---------------------------------------------------------------------------
# Algorithm bundles
# ---------------------------------------------------------------------------

def plane_seed_init(g: LocalGraph, roots: jax.Array):
    """Shared init: one bit-plane per root, value INF except 0 at the root.

    ``value`` is int32[n_pad, B] — levels for BFS/CC, hop distances for
    SSSP.  Frontier and seen start identical (the roots themselves).
    """
    b = roots.shape[0]
    planes = jnp.zeros((g.n_pad, b), jnp.bool_)
    planes = planes.at[roots, jnp.arange(b)].set(True)
    frontier = bitmap.pack_rows(planes)
    value = jnp.full((g.n_pad, b), INF, jnp.int32)
    value = value.at[roots, jnp.arange(b)].set(0)
    return frontier, frontier, value


def level_commit(value, new_mask, lvl):
    """BFS/CC apply: a vertex first reached at level ``lvl+1`` keeps it."""
    return jnp.where(new_mask, lvl + 1, value)


def minplus_commit(value, new_mask, lvl):
    """SSSP (unit weights) apply: min-plus relaxation dist = min(dist,
    lvl+1) over newly-relaxed planes.  With unit weights first arrival IS
    the minimum, so this converges in the same level-synchronous sweeps."""
    return jnp.minimum(value, jnp.where(new_mask, lvl + 1, INF))


def frontier_drained(sv: np.ndarray) -> bool:
    """Shared convergence predicate: no plane produced a new discovery."""
    return int(sv[SV_NF]) == 0


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """Per-algorithm bundle plugged into the shared engine.

    Frozen + module-level callables => hashable, so a program is a stable
    static jit argument (one compiled step per (program, budget, pallas)).
    ``undirected=True`` means the algorithm's semantics require the
    symmetrized graph (engine builders symmetrize before ``build_local_
    graph``; the engine itself is orientation-agnostic).
    """

    name: str
    init: Callable = plane_seed_init
    commit: Callable = level_commit
    done: Callable = frontier_drained
    combine: str = "or"          # plane merge op (see kernels.msbfs_propagate)
    undirected: bool = False


BFS = VertexProgram(name="bfs")
CC = VertexProgram(name="cc", undirected=True)
SSSP = VertexProgram(name="sssp", commit=minplus_commit)


class IntegrityError(RuntimeError):
    """A traversal integrity invariant was violated — the wave's answer
    cannot be trusted and must NOT be served.

    ScalaBFS trusts HBM ECC and a fixed PE pipeline to deliver correct
    frontier words; this software reproduction has no such guarantee, so
    the engine (``VertexProgramRunner`` with ``integrity != "off"``) folds
    cheap device-side invariant checks into the statvec protocol and
    raises this error the moment a check fails mid-run.  The serving
    supervisor (``repro.ft.EngineSupervisor``) classifies it as a
    KERNEL-CLASS transient fault: the wave is retried, and repeated
    violations walk the ``pallas -> jnp -> bool-plane`` demotion ladder —
    a corrupted kernel rung is the prime suspect.
    """


class BudgetOverflowError(RuntimeError):
    """Push edge budget still overflowed after ``max_overflow_retries``.

    By default the driver absorbs an overflowed (truncated) step silently
    by doubling the budget and re-running the level.  A serving deployment
    may prefer a bounded per-wave cost: with ``max_overflow_retries`` set,
    persistent overflow surfaces as this error carrying the last budget
    tried, so a fault-tolerance layer (``repro.ft.EngineSupervisor``) can
    retry the wave with an escalated starting budget instead of deepening
    inside the measured service time.
    """

    def __init__(self, budget: int, need: int, retries: int):
        super().__init__(
            f"push budget overflowed {retries}x (budget={budget}, "
            f"level needs ~{need} edges)")
        self.budget = int(budget)
        self.need = int(need)
        self.retries = int(retries)

PROGRAMS = {p.name: p for p in (BFS, CC, SSSP)}


def get_program(name: str) -> VertexProgram:
    try:
        return PROGRAMS[name]
    except KeyError:
        raise ValueError(f"unknown vertex program {name!r}; "
                         f"have {sorted(PROGRAMS)}") from None


# ---------------------------------------------------------------------------
# Shared packed-plane machinery (the extracted MS-BFS hot path).
#
# Frontier/seen state is a per-vertex PLANE mask — bit b of row v says
# "plane b has reached v" — packed into uint32[n_pad, ceil(B/32)] words
# (bitmap.pack_rows).  Every CSR/CSC edge read is shared by the whole
# batch: propagating along an edge is one 32/64-bit combine instead of B
# separate traversals (MS-BFS sharing; Then et al., VLDB'14).
#
# The packed words are the ONLY state representation: push gathers the
# frontier words of budgeted edges and scatter-combines them into the
# candidate words (Pallas msbfs_propagate / bitmap._scatter_or_rows);
# pull reduces each vertex's in-list with a segmented OR-scan over the
# static CSC edge stream (bitmap.segment_or_rows) — no unpack, no bool
# plane arrays, no scatter buffers.
# ---------------------------------------------------------------------------

# index of the OPTIONAL integrity slot appended to the statvec when a
# runner has integrity checking on (the base int32[7] layout lives in
# bfs_local; slot presence is a static jit choice, so clean runs pay it
# neither in compute nor in transfer width)
SV_CHECK = 7

# runner integrity levels, strictly ordered by cost:
#   off        — no checks (the historical engine)
#   invariants — device-side statvec invariants + host popcount/row checks
#   witness    — invariants + per-wave sampled parent-witness reduction
#   audit      — witness at engine level; the supervisor additionally
#                rate-samples a full differential audit against a
#                reference path (see repro.ft.integrity)
INTEGRITY_MODES = ("off", "invariants", "witness", "audit")


def _integrity_chk(frontier_w, seen_w, nb: int):
    """Device-side plane-word invariant residue (0 on an uncorrupted run).

    Three invariants the packed pipeline maintains by construction, folded
    into one popcount so the statvec grows by a single int32 slot:

    * ``frontier ⊆ seen`` — every step's frontier is last step's ``new``,
      which was OR-ed into ``seen`` in the same kernel.  A flipped plane
      word that conjures a frontier bit for an unseen vertex breaks this.
    * frontier pad bits beyond the true batch width are zero.
    * seen pad bits beyond the true batch width are zero.
    """
    pmask = bitmap.plane_mask(nb)
    return (bitmap.popcount(frontier_w & ~seen_w)
            + bitmap.popcount(frontier_w & ~pmask)
            + bitmap.popcount(seen_w & ~pmask))


def _vp_statvec(g: LocalGraph, new_w, seen_w, total, overflow, nb: int,
                chk=None):
    """Fused per-level stats: scheduler inputs for the NEXT level, this
    step's edge total/overflow, and the discovery popcount, stacked into
    one int32[7] so the driver fetches a single array per level (int32[8]
    with the integrity residue ``chk`` appended when checking is on).

    ``nb`` is the TRUE batch size: the pad planes of the last word are
    unseen by construction, so masking with the padded width would make
    every vertex count as "unseen by some plane" forever."""
    pmask = bitmap.plane_mask(nb)
    any_f = bitmap.any_rows(new_w)
    un_any = bitmap.any_rows(~seen_w & pmask)
    slots = [
        jnp.sum(any_f, dtype=jnp.int32),
        jnp.sum(jnp.where(any_f, g.out_deg, 0), dtype=jnp.int32),
        jnp.sum(jnp.where(un_any, g.in_deg, 0), dtype=jnp.int32),
        jnp.sum(un_any, dtype=jnp.int32),
        jnp.asarray(total, jnp.int32),
        jnp.asarray(overflow, jnp.int32),
        bitmap.popcount(new_w),
    ]
    if chk is not None:
        slots.append(jnp.asarray(chk, jnp.int32))
    return jnp.stack(slots)


def _vp_commit(g: LocalGraph, program: VertexProgram, new_w, seen_w, value,
               lvl, total, overflow, chk=None):
    """Per-level apply (the pipeline's single unpack point) + fused stats."""
    new_mask = bitmap.unpack_rows(new_w, value.shape[1])
    value2 = program.commit(value, new_mask, lvl)
    return value2, _vp_statvec(g, new_w, seen_w, total, overflow,
                               value.shape[1], chk)


def _propagate_edges(g: LocalGraph, frontier_w, seen_w, src, tgt, valid,
                     use_pallas: bool, combine: str = "or",
                     tile_rows: int | None = None):
    """Fused P2->P3 on packed words: cand[tgt] ⊕= frontier[src], then
    new = cand & ~seen, seen |= new.  Pallas kernel or jnp fallback.
    ``tile_rows`` selects the kernel variant (None = auto by plane-array
    footprint, 0 = whole-VMEM, > 0 = row-tiled at that size)."""
    if use_pallas:
        from repro.kernels import ops as kops
        new, seen2, _ = kops.msbfs_propagate(frontier_w, seen_w, src, tgt,
                                             valid, op=combine,
                                             tile_rows=tile_rows)
        return new, seen2
    if combine != "or":
        raise NotImplementedError(
            f"jnp fallback implements combine='or' only, got {combine!r} "
            "(payload-plane combines run through the Pallas kernel)")
    msg = frontier_w[jnp.maximum(src, 0)]
    cand = bitmap._scatter_or_rows(
        jnp.zeros_like(frontier_w), jnp.where(valid, tgt, g.n_pad), msg)
    new = cand & ~seen_w
    return new, seen_w | new


def _propagate_pull_scan(g: LocalGraph, frontier_w):
    """Candidate plane words for ALL vertices via the CSC edge stream:
    cand[v] = OR of frontier[parent] over v's in-list.  The edges are
    already grouped by child, so a segmented OR-scan + one gather at the
    segment ends replaces the scatter entirely (packed words throughout)."""
    if g.in_indices.shape[0] == 0:
        return jnp.zeros_like(frontier_w)
    msg = frontier_w[g.in_indices]                  # [E, nw] packed gather
    scan = bitmap.segment_or_rows(msg, g.in_seg_first)
    return jnp.where((g.in_seg_end >= 0)[:, None],
                     scan[jnp.maximum(g.in_seg_end, 0)], jnp.uint32(0))


def _propagate_pull_sparse(g: LocalGraph, frontier_w, seen_w, nb: int,
                           budget: int):
    """Budgeted pull: expand ONLY some-plane-unseen vertices' in-lists.

    The dense scan pull re-reads the whole CSC stream every level even when
    almost every vertex is already seen; the paper's pull reads just the
    unvisited vertices' in-lists (bounded by m_u).  This is the jnp
    analogue: expand_edges over the unseen-any set is vertex-major, so the
    segment boundaries fall out of the cumulative degrees and the same
    segmented OR-scan reduces each in-list — over ``budget`` edges instead
    of E.  Pays off on tail levels where m_u << E; the driver keeps the
    dense scan for full-stream levels (the expansion bookkeeping costs
    more per edge than the static-boundary scan).

    Returns (new, seen2, total); ``total > budget`` means the step was
    truncated and must be retried deeper (same overflow contract as push).
    """
    pmask = bitmap.plane_mask(nb)
    un_any = bitmap.any_rows(~seen_w & pmask)
    active, _ = compact_indices(un_any, g.n_pad)
    a = jnp.maximum(active, 0)
    deg = (g.in_indptr[a + 1] - g.in_indptr[a]) * (active >= 0)
    cum = jnp.cumsum(deg)
    total = cum[-1]
    e = jnp.arange(budget, dtype=jnp.int32)
    owner = jnp.searchsorted(cum, e, side="right").astype(jnp.int32)
    owner_c = jnp.minimum(owner, active.shape[0] - 1)
    start = cum[owner_c] - deg[owner_c]
    child = active[owner_c]
    eidx = g.in_indptr[jnp.maximum(child, 0)] + (e - start)
    valid = e < total
    parent = g.in_indices[jnp.where(valid, eidx, 0)]
    msg = jnp.where(valid[:, None], frontier_w[parent], jnp.uint32(0))
    scan = bitmap.segment_or_rows(msg, e == start)
    # one segment end per active vertex -> unique scatter targets, so a
    # plain row set (mode="drop" for the pad slots) lands the per-vertex OR
    endpos = jnp.clip(cum - 1, 0, budget - 1)
    rows = jnp.where((deg > 0) & (active >= 0), active, g.n_pad)
    cand = jnp.zeros((g.n_pad + 1, frontier_w.shape[1]), jnp.uint32)
    cand = cand.at[rows].set(scan[endpos], mode="drop")[:-1]
    new = cand & ~seen_w
    return new, seen_w | new, total


@jax.jit
def _plane_traversed(g: LocalGraph, value):
    """int32[B]: per-plane traversed edges = sum of out-degrees over the
    vertices each plane reached (the paper's TEPS numerator, one entry per
    source so pad planes can be sliced off without a host recount)."""
    reached = value[: g.n] < INF
    return jnp.sum(jnp.where(reached, g.out_deg[: g.n, None], 0),
                   axis=0, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("budget",))
def _witness_check(g: LocalGraph, value, sample, budget: int):
    """Sampled parent-witness audit, one fused reduction.

    For every sampled vertex ``v`` and plane ``p`` with a finite non-root
    value, SOME in-neighbor ``u`` must hold ``value[u,p] == value[v,p]-1``
    — the parent that discovered it (level-synchronous BFS/CC and
    unit-weight SSSP all satisfy this exactly).  The K sampled in-lists
    are expanded with the same budgeted owner-slot pattern as the sparse
    pull, the witness predicate is OR-reduced per (vertex, plane), and the
    result collapses to int32[2] = (violations, truncated) so it folds
    into the run's final fetch (``host_transfers`` invariant intact).
    ``truncated != 0`` means the sampled in-lists overflowed ``budget``
    and the violation count is unusable — the driver skips, not raises.
    """
    k = sample.shape[0]
    deg = g.in_indptr[sample + 1] - g.in_indptr[sample]
    cum = jnp.cumsum(deg)
    total = cum[-1]
    e = jnp.arange(budget, dtype=jnp.int32)
    owner = jnp.searchsorted(cum, e, side="right").astype(jnp.int32)
    owner_c = jnp.minimum(owner, k - 1)
    start = cum[owner_c] - deg[owner_c]
    child = sample[owner_c]
    eidx = g.in_indptr[child] + (e - start)
    valid = (e < total) & (e < jnp.int32(budget))
    parent = g.in_indices[jnp.where(valid, eidx, 0)]
    ok_e = valid[:, None] & (value[parent] == value[child] - 1)
    ok = jnp.zeros((k + 1, value.shape[1]), jnp.bool_)
    ok = ok.at[jnp.where(valid, owner_c, k)].max(ok_e, mode="drop")[:-1]
    vals = value[sample]                              # [K, B]
    need = (vals > 0) & (vals < INF)
    return jnp.stack([jnp.sum(need & ~ok, dtype=jnp.int32),
                      jnp.asarray(total > budget, jnp.int32)])


def _xor_plane_bit(words, vertex: int, plane: int):
    """Flip one bit of one packed plane word (the chaos layer's HBM
    bit-flip analogue; see ``repro.ft.FaultyEngine``).  XOR, not OR: a
    flip of a set bit suppresses a discovery rather than conjuring one."""
    word, bit = divmod(int(plane), bitmap.WORD_BITS)
    return words.at[int(vertex), word].set(
        words[int(vertex), word] ^ jnp.uint32(1 << bit))


@partial(jax.jit, static_argnames=("program", "check"))
def vp_init_state(g: LocalGraph, roots: jax.Array, program: VertexProgram,
                  check: bool = False):
    frontier, seen, value = program.init(g, roots)
    chk = (_integrity_chk(frontier, seen, roots.shape[0]) if check
           else None)
    return (frontier, seen, value,
            _vp_statvec(g, frontier, seen, 0, 0, roots.shape[0], chk))


@partial(jax.jit, static_argnames=("program", "budget", "use_pallas",
                                   "tile_rows", "check"))
def vp_push_step(g: LocalGraph, frontier_w, seen_w, value, lvl,
                 program: VertexProgram, budget: int,
                 use_pallas: bool = False, tile_rows: int | None = None,
                 check: bool = False):
    """Batched push on packed words: expand out-lists of any-plane
    frontier vertices; each budgeted edge carries its endpoint's packed
    plane word straight into the candidate planes (fused P2->P3)."""
    # the integrity residue is computed from the step's INPUT state: it
    # rides the output statvec but indicts the words the step consumed
    chk = (_integrity_chk(frontier_w, seen_w, value.shape[1]) if check
           else None)
    any_f = bitmap.any_rows(frontier_w)
    active, _ = compact_indices(any_f, g.n_pad)
    src, nbr, valid, total = expand_edges(active, g.out_indptr,
                                          g.out_indices, budget)
    new, seen2 = _propagate_edges(g, frontier_w, seen_w, src, nbr, valid,
                                  use_pallas, program.combine, tile_rows)
    value2, statvec = _vp_commit(g, program, new, seen2, value, lvl, total,
                                 total > budget, chk)
    return new, seen2, value2, statvec


@partial(jax.jit, static_argnames=("program", "budget", "use_pallas",
                                   "tile_rows", "check"))
def vp_pull_step(g: LocalGraph, frontier_w, seen_w, value, lvl,
                 program: VertexProgram, budget: int = 0,
                 use_pallas: bool = False, tile_rows: int | None = None,
                 check: bool = False):
    """Batched pull on packed words.

    Default path (``budget == 0``): dense segmented OR-scan over the whole
    CSC edge stream (never overflows).  ``budget > 0`` selects the sparse
    budgeted pull — only some-plane-unseen vertices' in-lists are expanded
    (``_propagate_pull_sparse``), which the driver uses on tail levels
    where m_u << E.  Pallas path: budgeted expansion through the fused
    propagate kernel."""
    chk = (_integrity_chk(frontier_w, seen_w, value.shape[1]) if check
           else None)
    if use_pallas:
        un_any = bitmap.any_rows(
            ~seen_w & bitmap.plane_mask(value.shape[1]))
        active, _ = compact_indices(un_any, g.n_pad)
        child, parent, valid, total = expand_edges(
            active, g.in_indptr, g.in_indices, budget)
        new, seen2 = _propagate_edges(g, frontier_w, seen_w, parent, child,
                                      valid, True, program.combine,
                                      tile_rows)
        overflow = total > budget
    elif budget:
        new, seen2, total = _propagate_pull_sparse(
            g, frontier_w, seen_w, value.shape[1], budget)
        overflow = total > budget
    else:
        cand = _propagate_pull_scan(g, frontier_w)
        new = cand & ~seen_w
        seen2 = seen_w | new
        total = jnp.int32(g.in_indices.shape[0])
        overflow = jnp.int32(0)
    value2, statvec = _vp_commit(g, program, new, seen2, value, lvl, total,
                                 overflow, chk)
    return new, seen2, value2, statvec


def vp_reference(g: LocalGraph, roots, program: VertexProgram = BFS,
                 max_iters: int | None = None):
    """Fully-jit dense vertex-program loop (packed words, pull-form
    edge-parallel steps).  Returns the finalized value rows [B, n]."""
    roots = jnp.asarray(roots, jnp.int32)
    max_iters = max_iters or g.n_pad
    frontier0, seen0, value0 = program.init(g, roots)

    def cond(state):
        frontier, seen, value, lvl = state
        return (bitmap.popcount(frontier) > 0) & (lvl < max_iters)

    def body(state):
        frontier, seen, value, lvl = state
        cand = _propagate_pull_scan(g, frontier)
        new = cand & ~seen
        seen = seen | new
        new_mask = bitmap.unpack_rows(new, roots.shape[0])
        value = program.commit(value, new_mask, lvl)
        return new, seen, value, lvl + 1

    frontier, seen, value, lvl = jax.lax.while_loop(
        cond, body, (frontier0, seen0, value0, jnp.int32(0)))
    return value[: g.n].T


def msbfs_reference(g: LocalGraph, roots, max_iters: int | None = None):
    """Fully-jit dense MS-BFS loop (packed words).  Returns level [B, n]."""
    return vp_reference(g, roots, BFS, max_iters)


# ---------------------------------------------------------------------------
# Results + the generic one-sync-per-level driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VertexProgramResult:
    levels: np.ndarray          # int32[B, n] — one value row per plane
    batch: int
    iterations: int
    # edges actually streamed per level.  NOTE: the packed pipeline's
    # scan-based pull reads the WHOLE CSC edge stream per pull level
    # (that is its cost model), so this is not comparable edge-for-edge
    # with the budgeted bool-plane baseline's m_u-bounded pulls.
    edges_inspected: int
    push_iters: int
    pull_iters: int
    traversed_edges: int        # summed over all planes (paper §VI-A metric)
    seconds: float
    host_transfers: int = 0     # blocking device->host fetches during run
    algo: str = "bfs"
    labels: np.ndarray | None = None   # CC: int64[n] min-seed labels
    overflow_retries: int = 0   # levels re-run after a truncated push/pull
    budget: int = 0             # final edge budget the run settled on

    @property
    def distances(self) -> np.ndarray:
        """SSSP alias: the value rows are hop distances."""
        return self.levels

    @property
    def aggregate_teps(self) -> float:
        return self.traversed_edges / max(self.seconds, 1e-12)

    @property
    def gteps(self) -> float:
        return self.aggregate_teps / 1e9


# Backwards-compatible name: BFS results are the same record.
MSBFSResult = VertexProgramResult


class VertexProgramRunner:
    """Python-driven hybrid vertex-program engine over a batch of roots.

    The per-iteration structure is the paper's pipeline (stats -> mode ->
    gather/scan step -> P3 commit) with one bit-plane per root; direction
    choice uses any-plane frontier / any-plane-unseen statistics.  Plane
    state never unpacks between P1 and the commit, and each level costs
    exactly one blocking device->host transfer (the fused stats vector):
    ``result.host_transfers == iterations + 2``.

    ``run`` is the SHARED entry for every algorithm: it validates the
    roots once (negative / >= |V| roots would scatter silently out of
    bounds) so no instantiation can forget to.
    """

    program: VertexProgram = BFS

    def __init__(self, g: LocalGraph, program: VertexProgram | None = None,
                 sched: SchedulerConfig | None = None,
                 init_budget: int = 1 << 15, use_pallas: bool = False,
                 max_overflow_retries: int | None = None,
                 tile_rows: int | None = None, sparse_pull: bool = False,
                 integrity: str = "off", witness_k: int = 64,
                 witness_budget: int = 4096,
                 integrity_seed: int | None = 0):
        if integrity not in INTEGRITY_MODES:
            raise ValueError(f"integrity must be one of {INTEGRITY_MODES}, "
                             f"got {integrity!r}")
        self.g = g
        self.program = program if program is not None else type(self).program
        self.sched = sched or SchedulerConfig()
        self.init_budget = init_budget
        self.use_pallas = use_pallas
        # per-wave integrity validation (see INTEGRITY_MODES).  Mutable
        # between waves: the serving supervisor flips it on the engine it
        # wraps.  "audit"'s differential re-run lives in the supervisor;
        # at engine level it behaves like "witness".
        self.integrity = integrity
        self.witness_k = witness_k
        self.witness_budget = witness_budget
        self._witness_rng = np.random.default_rng(integrity_seed)
        # exact-once plane corruption hook: (level, vertex, plane) set by
        # the chaos layer (repro.ft.FaultyEngine) to XOR one frontier bit
        # right before that level's step; consumed (or cleared) per run
        self._corrupt_plane: tuple[int, int, int] | None = None
        # Pallas propagate variant: None = auto by plane-array footprint
        # (kernels.ops.propagate_plan), 0 = force whole-VMEM, > 0 = force
        # row tiles of that many vertices
        self.tile_rows = tile_rows
        # budgeted pull on tail levels where m_u is far below the full CSC
        # stream (see _propagate_pull_sparse); off by default to preserve
        # the dense scan's cost model (edges_inspected counts E per pull)
        self.sparse_pull = sparse_pull
        # None = deepen forever (absorb overflow silently, the historical
        # behavior); an int bounds per-wave re-runs and surfaces persistent
        # overflow as BudgetOverflowError for the serving FT layer
        self.max_overflow_retries = max_overflow_retries
        self._transfers = 0
        self.last_stats: dict = {}
        # fetched once here so the TEPS accounting after each run is not
        # an extra (uncounted) device->host transfer
        self._out_deg_np = np.asarray(g.out_deg)[: g.n]

    # -- engine protocol --------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return int(self.g.n)

    @property
    def out_deg(self) -> np.ndarray:
        """Out-degrees [n] (the engine protocol's TEPS numerator input)."""
        return self._out_deg_np

    def _fetch(self, arr) -> np.ndarray:
        self._transfers += 1
        return np.asarray(arr)

    def _fetch_pair(self, a, b):
        """One blocking device->host round trip for two device values."""
        self._transfers += 1
        return jax.device_get((a, b))

    def _fetch_many(self, *vals):
        """One blocking device->host round trip for N device values (the
        final fetch grows a witness verdict without a second sync)."""
        self._transfers += 1
        return jax.device_get(vals)

    # -- integrity guards (active when ``integrity != "off"``) ------------
    def _guard_sv(self, sv: np.ndarray, lvl: int, nb: int,
                  discovered: int) -> None:
        """Host-side checks on the just-fetched statvec: the device-side
        residue slot, frontier-count/popcount agreement, discovery-total
        bound and loop-termination bound.  Raises IntegrityError."""
        if int(sv[SV_CHECK]) != 0:
            raise IntegrityError(
                f"plane-word invariant violated at level {lvl}: "
                f"{int(sv[SV_CHECK])} corrupt frontier/seen/pad bits "
                "(frontier ⊄ seen or dirty pad bits)")
        if (int(sv[SV_NF]) > 0) != (int(sv[SV_COUNT]) > 0):
            raise IntegrityError(
                f"statvec inconsistent at level {lvl}: frontier rows "
                f"{int(sv[SV_NF])} vs discovery popcount "
                f"{int(sv[SV_COUNT])}")
        if discovered + int(sv[SV_COUNT]) > self.g.n * nb:
            raise IntegrityError(
                f"cumulative discoveries {discovered + int(sv[SV_COUNT])} "
                f"exceed |V| x planes = {self.g.n * nb} at level {lvl} "
                "(each (vertex, plane) pair can be discovered once)")
        if lvl > self.g.n:
            raise IntegrityError(
                f"nonterminating traversal: level {lvl} exceeds |V| = "
                f"{self.g.n} (discovery popcounts must drain within n "
                "levels)")

    def _guard_rows(self, rows: np.ndarray, roots: np.ndarray,
                    iters: int) -> None:
        """Final value rows must be 0 at each plane's own root and either
        INF or bounded by the iteration count everywhere else."""
        bad = (rows != int(INF)) & ((rows < 0) | (rows > iters))
        if bad.any():
            v = int(np.argwhere(bad)[0][1])
            raise IntegrityError(
                f"{int(bad.sum())} result values outside "
                f"[0, {iters}] ∪ {{INF}} (first at vertex {v})")
        at_root = rows[np.arange(roots.size), roots]
        if np.any(at_root != 0):
            raise IntegrityError(
                f"{int(np.sum(at_root != 0))} planes lost their root "
                "(value at own root != 0)")

    def _pull_budget(self, m_u: int) -> int:
        """Sparse-pull budget for this level, or 0 to keep the dense scan.

        ``m_u`` bounds the expansion exactly (every some-plane-unseen
        vertex contributes its whole in-list), so the next power of two
        above it can never overflow.  The sparse path's per-edge cost is
        several times the static-boundary scan's, so it only engages well
        below the full CSC stream — full-ish levels stay dense."""
        cap = int(self.g.in_indices.shape[0])
        pb = 1 << max(12, (max(m_u, 1) - 1).bit_length())
        return pb if pb * 8 <= cap else 0

    def run(self, roots, *, budget: int | None = None) -> VertexProgramResult:
        # validate BEFORE the int32 cast: a >= 2**31 root must error, not
        # wrap.  This is the shared entry — every algorithm goes through it.
        roots = validate_roots(np.asarray(roots), self.g.n).astype(np.int32)
        self._transfers = 0
        return self._finalize(self._run_packed(roots, budget), roots)

    def run_batch(self, roots, *, budget: int | None = None) -> np.ndarray:
        """Engine-protocol entry: value rows [B, n] + ``last_stats``.

        ``budget`` overrides ``init_budget`` for THIS wave only — the
        serving supervisor uses it to escalate the edge budget on a retry
        after persistent push-budget overflow, without re-tuning the
        engine's steady-state starting point.
        """
        return self.run(roots, budget=budget).levels

    def _finalize(self, res: VertexProgramResult,
                  roots: np.ndarray) -> VertexProgramResult:
        """Per-algorithm post-processing hook (e.g. CC labels)."""
        return res

    # -- the extracted one-sync-per-level loop ----------------------------
    def _run_packed(self, roots: np.ndarray,
                    budget_override: int | None = None
                    ) -> VertexProgramResult:
        g, program = self.g, self.program
        b = int(roots.size)
        check = self.integrity != "off"
        witness = self.integrity in ("witness", "audit")
        corrupt, self._corrupt_plane = self._corrupt_plane, None
        pcs: list[int] = []         # per-level discovery popcounts
        t0 = time.perf_counter()
        frontier, seen, value, statvec = vp_init_state(
            g, jnp.asarray(roots), program, check=check)
        sv = self._fetch(statvec)
        if check:
            self._guard_sv(sv, 0, b, 0)
        pcs.append(int(sv[SV_COUNT]))
        mode = PUSH
        lvl = 0
        inspected = 0
        push_iters = pull_iters = 0
        overflow_retries = 0
        # no point budgeting past the whole edge array (keeps the budgeted
        # kernels small on tiny graphs); the overflow loop still deepens
        budget = min(budget_override or self.init_budget,
                     max(g.out_indices.shape[0], g.in_indices.shape[0]) + 1)
        while not program.done(sv):
            mode = choose_mode_host(self.sched, mode, int(sv[SV_NF]),
                                    int(sv[SV_MF]), int(sv[SV_MU]), g.n,
                                    int(sv[SV_NU]))
            # the scan-based pull is dense over the CSC edge stream: only
            # push (and the budgeted Pallas/sparse pulls) need a budget
            budgeted = mode == PUSH or self.use_pallas
            step_budget = 0
            if budgeted:
                need = int(sv[SV_MF]) if mode == PUSH else int(sv[SV_MU])
                cap = (g.out_indices if mode == PUSH
                       else g.in_indices).shape[0]
                while budget < min(need, cap + 1):
                    budget *= 2
                step_budget = budget
            elif self.sparse_pull:
                # per-level choice (NOT the ratcheting push budget): tail
                # levels shrink, so the pull budget must shrink with them
                step_budget = self._pull_budget(int(sv[SV_MU]))
            step = vp_push_step if mode == PUSH else vp_pull_step
            if corrupt is not None and lvl == int(corrupt[0]):
                # chaos hook: flip one frontier plane bit, exact-once
                frontier = _xor_plane_bit(frontier, corrupt[1], corrupt[2])
                corrupt = None
            # retry from the PRE-step seen: an overflowed (truncated) step
            # may have committed a partial discovery set
            state0 = (frontier, seen, value)
            frontier, seen, value, statvec = step(
                g, *state0, np.int32(lvl), program, step_budget,
                self.use_pallas, self.tile_rows, check=check)
            sv = self._fetch(statvec)
            if check:
                self._guard_sv(sv, lvl, b, sum(pcs))
            while step_budget and bool(sv[SV_OVERFLOW]):
                overflow_retries += 1   # surfaced in last_stats / result
                if (self.max_overflow_retries is not None
                        and overflow_retries > self.max_overflow_retries):
                    raise BudgetOverflowError(step_budget, int(sv[SV_MF]),
                                              overflow_retries)
                step_budget *= 2       # HBM-reader queue overflow: deepen
                if budgeted:
                    budget = step_budget
                frontier, seen, value, statvec = step(
                    g, *state0, np.int32(lvl), program, step_budget,
                    self.use_pallas, self.tile_rows, check=check)
                sv = self._fetch(statvec)
                if check:
                    self._guard_sv(sv, lvl, b, sum(pcs))
            pcs.append(int(sv[SV_COUNT]))
            lvl += 1
            inspected += int(sv[SV_TOTAL])
            if mode == PUSH:
                push_iters += 1
            else:
                pull_iters += 1
        value.block_until_ready()
        dt = time.perf_counter() - t0
        # per-plane traversed-edge counts, computed ON DEVICE and fetched
        # with the value rows in ONE blocking transfer (host_transfers
        # stays iterations + 2).  Each plane's count is <= E so int32 is
        # safe; the cross-plane sum happens on host in int64.  The numpy
        # recount this replaces cost tens of ms per wide wave.  With the
        # witness audit on, its int32[2] verdict rides the SAME fetch.
        wit = None
        if witness:
            k = min(self.witness_k, g.n)
            sample = jnp.asarray(
                self._witness_rng.integers(0, g.n, size=k), jnp.int32)
            rows_cm, trav_np, wit = self._fetch_many(
                value[: g.n], _plane_traversed(g, value),
                _witness_check(g, value, sample, self.witness_budget))
        else:
            rows_cm, trav_np = self._fetch_pair(value[: g.n],
                                                _plane_traversed(g, value))
        rows = rows_cm.T                             # [B, n]
        if check:
            self._guard_rows(rows, roots, lvl)
            if wit is not None and not int(wit[1]) and int(wit[0]):
                raise IntegrityError(
                    f"witness audit failed: {int(wit[0])} sampled "
                    "(vertex, plane) discoveries have no in-neighbor at "
                    "value - 1")
        res = self._result(rows, b, lvl, inspected, push_iters,
                           pull_iters, dt, overflow_retries, budget,
                           trav_vec=trav_np)
        self.last_stats["discovery_popcounts"] = pcs
        if check:
            self.last_stats["integrity"] = dict(
                mode=self.integrity,
                sv_checks=len(pcs),
                witness_sampled=(0 if wit is None
                                 else min(self.witness_k, g.n)),
                witness_truncated=bool(wit is not None and int(wit[1])))
        return res

    def _result(self, rows, b, lvl, inspected, push_iters, pull_iters,
                dt, overflow_retries: int = 0, budget: int = 0,
                trav_vec: np.ndarray | None = None) -> VertexProgramResult:
        if trav_vec is None:
            traversed = count_traversed_edges(self._out_deg_np, rows)
        else:
            traversed = int(np.sum(trav_vec, dtype=np.int64))
        res = VertexProgramResult(
            levels=rows, batch=b, iterations=lvl, edges_inspected=inspected,
            push_iters=push_iters, pull_iters=pull_iters,
            traversed_edges=traversed, seconds=dt,
            host_transfers=self._transfers, algo=self.program.name,
            overflow_retries=overflow_retries, budget=budget)
        self.last_stats = dict(
            iterations=res.iterations, edges_inspected=res.edges_inspected,
            push_iters=res.push_iters, pull_iters=res.pull_iters,
            batch=res.batch, traversed_edges=res.traversed_edges,
            seconds=res.seconds, host_transfers=res.host_transfers,
            algo=res.algo, overflow_retries=res.overflow_retries,
            budget=res.budget)
        if trav_vec is not None:
            # per-plane counts let the serving layer account pad slots out
            # of TEPS without re-counting from the sliced level rows
            # (plain ints: last_stats must stay JSON-serializable)
            self.last_stats["traversed_per_plane"] = [
                int(x) for x in trav_vec]
        return res


# ---------------------------------------------------------------------------
# Instantiation 1: batched multi-source BFS (+ the legacy bool-plane
# baseline, kept as `MultiSourceBFSRunner(packed=False)` for differential
# tests and the throughput benchmark's "packed: off" arm).
# ---------------------------------------------------------------------------

def _p3_update_ms(cand_w, seen_w, use_pallas: bool):
    """Batched P3: fused per-plane Pallas kernel or plain jnp."""
    if use_pallas:
        from repro.kernels import ops as kops
        new_t, seen_t, _ = kops.fused_frontier_update_batch(
            cand_w.T, seen_w.T)       # planes-major for the kernel grid
        return new_t.T, seen_t.T
    new = cand_w & ~seen_w
    return new, seen_w | new


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def _boolplane_push_step(g: LocalGraph, frontier_w, seen_w, budget: int,
                         use_pallas: bool = False):
    """Bool-plane push: unpacks the whole frontier, builds a [budget, B]
    bool message array and a [n_pad+1, nb] bool scatter buffer per level."""
    nb = frontier_w.shape[1] * bitmap.WORD_BITS
    fmask = bitmap.unpack_rows(frontier_w)            # [n_pad, B']
    any_f = bitmap.any_rows(frontier_w)
    active, _ = compact_indices(any_f, g.n_pad)
    src, nbr, valid, total = expand_edges(active, g.out_indptr,
                                          g.out_indices, budget)
    msg = fmask[jnp.maximum(src, 0)] & valid[:, None]  # [budget, B']
    tgt = jnp.where(valid, nbr, g.n_pad)
    cand = jnp.zeros((g.n_pad + 1, nb), jnp.bool_)
    cand = cand.at[tgt].max(msg, mode="drop")[:-1]
    cand_w = bitmap.pack_rows(cand)
    new, seen2 = _p3_update_ms(cand_w, seen_w, use_pallas)
    return new, seen2, total, total > budget


@partial(jax.jit, static_argnames=("budget", "use_pallas"))
def _boolplane_pull_step(g: LocalGraph, frontier_w, seen_w, budget: int,
                         use_pallas: bool = False):
    """Bool-plane pull: vertices unseen by SOME source read their in-lists
    once and OR their parents' frontier masks (via bool plane arrays)."""
    nb = frontier_w.shape[1] * bitmap.WORD_BITS
    pmask = bitmap.plane_mask(nb)
    fmask = bitmap.unpack_rows(frontier_w)
    un_any = bitmap.any_rows(~seen_w & pmask)
    active, _ = compact_indices(un_any, g.n_pad)
    child, parent, valid, total = expand_edges(active, g.in_indptr,
                                               g.in_indices, budget)
    msg = fmask[jnp.maximum(parent, 0)] & valid[:, None]
    tgt = jnp.where(valid, child, g.n_pad)
    cand = jnp.zeros((g.n_pad + 1, nb), jnp.bool_)
    cand = cand.at[tgt].max(msg, mode="drop")[:-1]
    cand_w = bitmap.pack_rows(cand)
    new, seen2 = _p3_update_ms(cand_w, seen_w, use_pallas)
    return new, seen2, total, total > budget


@jax.jit
def _ms_iter_stats(g: LocalGraph, frontier_w, seen_w):
    nb = frontier_w.shape[1] * bitmap.WORD_BITS
    pmask = bitmap.plane_mask(nb)
    any_f = bitmap.any_rows(frontier_w)
    un_any = bitmap.any_rows(~seen_w & pmask)
    n_f = jnp.sum(any_f, dtype=jnp.int32)
    m_f = jnp.sum(jnp.where(any_f, g.out_deg, 0), dtype=jnp.int32)
    m_u = jnp.sum(jnp.where(un_any, g.in_deg, 0), dtype=jnp.int32)
    n_u = jnp.sum(un_any, dtype=jnp.int32)
    return n_f, m_f, m_u, n_u


class MultiSourceBFSRunner(VertexProgramRunner):
    """Batched hybrid MS-BFS: the BFS instantiation of the engine.

    ``packed=True`` (default) runs the shared packed-word pipeline.
    ``packed=False`` preserves the pre-packed bool-plane implementation as
    a differential/benchmark baseline (bool planes + per-scalar syncs).
    """

    program = BFS

    def __init__(self, g: LocalGraph, sched: SchedulerConfig | None = None,
                 init_budget: int = 1 << 15, use_pallas: bool = False,
                 packed: bool = True,
                 max_overflow_retries: int | None = None,
                 tile_rows: int | None = None, sparse_pull: bool = False,
                 integrity: str = "off", witness_k: int = 64,
                 witness_budget: int = 4096,
                 integrity_seed: int | None = 0):
        super().__init__(g, BFS, sched, init_budget, use_pallas,
                         max_overflow_retries, tile_rows, sparse_pull,
                         integrity, witness_k, witness_budget,
                         integrity_seed)
        self.packed = packed

    def run(self, roots, *, budget: int | None = None) -> VertexProgramResult:
        # NOTE: the bool-plane baseline performs no integrity checks — it
        # IS the reference the supervisor's differential audit compares
        # against, and the demotion ladder's last rung
        if self.packed:
            return super().run(roots, budget=budget)
        roots = validate_roots(np.asarray(roots), self.g.n).astype(np.int32)
        self._transfers = 0
        return self._run_boolplane(roots, budget)

    def _run_boolplane(self, roots: np.ndarray,
                       budget_override: int | None = None
                       ) -> VertexProgramResult:
        """Pre-packed-pipeline driver (bool planes + per-scalar syncs)."""
        g = self.g
        b = int(roots.size)
        frontier, seen, level = plane_seed_init(g, jnp.asarray(roots))
        mode = jnp.int32(PUSH)
        lvl = 0
        inspected = 0
        push_iters = pull_iters = 0
        overflow_retries = 0
        budget = budget_override or self.init_budget
        t0 = time.perf_counter()
        while True:
            n_f, m_f, m_u, n_u = _ms_iter_stats(g, frontier, seen)
            n_f, m_f, m_u, n_u = (self._fetch(n_f), self._fetch(m_f),
                                  self._fetch(m_u), self._fetch(n_u))
            if int(n_f) == 0:
                break
            mode = choose_mode(self.sched, mode, n_f, m_f, m_u, g.n, n_u)
            is_push = int(self._fetch(mode)) == PUSH  # another per-level sync
            step = (_boolplane_push_step if is_push
                    else _boolplane_pull_step)
            need = int(m_f) if is_push else int(m_u)
            while budget < min(need, g.out_indices.shape[0] + 1):
                budget *= 2
            seen0 = seen
            new, seen, total, overflow = step(g, frontier, seen0, budget,
                                              self.use_pallas)
            while bool(self._fetch(overflow)):
                overflow_retries += 1
                if (self.max_overflow_retries is not None
                        and overflow_retries > self.max_overflow_retries):
                    raise BudgetOverflowError(budget, int(need),
                                              overflow_retries)
                budget *= 2
                new, seen, total, overflow = step(g, frontier, seen0,
                                                  budget, self.use_pallas)
            new_mask = bitmap.unpack_rows(new, b)
            level = jnp.where(new_mask, lvl + 1, level)
            frontier = new
            lvl += 1
            inspected += int(self._fetch(total))
            if is_push:
                push_iters += 1
            else:
                pull_iters += 1
        level.block_until_ready()
        dt = time.perf_counter() - t0
        levels = self._fetch(level[: g.n]).T       # [B, n]
        return self._result(levels, b, lvl, inspected, push_iters,
                            pull_iters, dt, overflow_retries, budget)


# ---------------------------------------------------------------------------
# Instantiation 2: batched multi-seed connected components.
# ---------------------------------------------------------------------------

def component_labels(levels: np.ndarray, seeds: np.ndarray) -> np.ndarray:
    """Per-vertex CC labels from the multi-seed reach levels.

    ``label[v]`` = the smallest seed VERTEX ID whose component contains
    ``v`` (all seeds in one component reach the same vertex set at
    convergence, so labels are uniform per component), or -1 when no seed
    reaches ``v``."""
    levels = np.asarray(levels)
    seeds = np.asarray(seeds, np.int64)
    reach = levels < int(INF)                        # [B, n]
    big = np.iinfo(np.int64).max
    lab = np.where(reach, seeds[:, None], big).min(axis=0)
    return np.where(lab == big, -1, lab)


class ConnectedComponentsRunner(VertexProgramRunner):
    """Batched multi-seed CC: one plane per seed, flood fill to fixpoint.

    The engine must be built over the SYMMETRIZED graph (components are an
    undirected notion) — use :meth:`from_csr`, or pass a ``LocalGraph``
    built from ``repro.graph.symmetrize_csr`` output.  ``run(seeds)``
    returns hop levels from each seed ([B, n]; membership = ``level <
    INF``) plus ``result.labels`` — the classic per-vertex component
    labeling (min seed id, -1 for vertices no seed reaches).
    """

    program = CC

    @classmethod
    def from_csr(cls, csr, **kw) -> "ConnectedComponentsRunner":
        """Build from a (possibly directed) CSR: symmetrize, then wire up."""
        from repro.core.bfs_local import build_local_graph
        from repro.graph.csr import symmetrize_csr, transpose_csr
        sym = symmetrize_csr(csr)
        return cls(build_local_graph(sym, transpose_csr(sym)), **kw)

    def _finalize(self, res: VertexProgramResult,
                  roots: np.ndarray) -> VertexProgramResult:
        res.labels = component_labels(res.levels, roots)
        self.last_stats["components"] = int(
            np.unique(res.labels[res.labels >= 0]).size)
        return res


# ---------------------------------------------------------------------------
# Instantiation 3: batched SSSP (unit-weight hop distances).
# ---------------------------------------------------------------------------

class SSSPRunner(VertexProgramRunner):
    """Batched single-source shortest paths, unit edge weights.

    One frontier plane per source; the apply is a min-plus relaxation
    (``dist = min(dist, lvl + 1)`` over newly-relaxed planes) rather than
    BFS's first-touch level write — with unit weights both converge to
    hop distances, which is what the differential tests pin against a
    dense Bellman–Ford oracle.  ``result.distances`` ([B, n], INF =
    unreachable) aliases the value rows.
    """

    program = SSSP
