from repro.core import bitmap
from repro.core.bfs_local import (BFSEngine, BFSResult, BFSRunner,
                                  LocalGraph, MSBFSResult,
                                  MultiSourceBFSRunner, bfs_oracle,
                                  bfs_reference, build_local_graph,
                                  count_traversed_edges,
                                  engine_num_vertices, msbfs_reference,
                                  validate_roots)
from repro.core.partition import PartitionedGraph, partition_graph
from repro.core.scheduler import (PULL, PUSH, SchedulerConfig, choose_mode,
                                  choose_mode_host)

__all__ = [
    "bitmap", "BFSEngine", "BFSResult", "BFSRunner", "LocalGraph",
    "MSBFSResult", "MultiSourceBFSRunner", "bfs_oracle", "bfs_reference",
    "build_local_graph", "count_traversed_edges", "engine_num_vertices",
    "msbfs_reference", "validate_roots", "PartitionedGraph",
    "partition_graph", "PULL", "PUSH", "SchedulerConfig", "choose_mode",
    "choose_mode_host",
]
