from repro.core import bitmap
from repro.core.bfs_local import (BFSEngine, BFSResult, BFSRunner,
                                  LocalGraph, bfs_oracle, bfs_reference,
                                  build_local_graph, count_traversed_edges,
                                  engine_num_vertices, validate_roots)
from repro.core.partition import PartitionedGraph, partition_graph
from repro.core.scheduler import (PULL, PUSH, SchedulerConfig, choose_mode,
                                  choose_mode_host)
from repro.core.vertex_program import (BFS, CC, INTEGRITY_MODES, PROGRAMS,
                                       SSSP, SV_CHECK,
                                       BudgetOverflowError,
                                       ConnectedComponentsRunner,
                                       IntegrityError, MSBFSResult,
                                       MultiSourceBFSRunner, SSSPRunner,
                                       VertexProgram, VertexProgramResult,
                                       VertexProgramRunner,
                                       component_labels, get_program,
                                       msbfs_reference, vp_reference)

__all__ = [
    "bitmap", "BFSEngine", "BFSResult", "BFSRunner", "LocalGraph",
    "MSBFSResult", "MultiSourceBFSRunner", "bfs_oracle", "bfs_reference",
    "build_local_graph", "count_traversed_edges", "engine_num_vertices",
    "msbfs_reference", "validate_roots", "PartitionedGraph",
    "partition_graph", "PULL", "PUSH", "SchedulerConfig", "choose_mode",
    "choose_mode_host", "BFS", "CC", "SSSP", "PROGRAMS",
    "INTEGRITY_MODES", "SV_CHECK", "IntegrityError",
    "BudgetOverflowError", "VertexProgram",
    "VertexProgramResult", "VertexProgramRunner",
    "ConnectedComponentsRunner", "SSSPRunner", "component_labels",
    "get_program", "vp_reference",
]
