from repro.core import bitmap
from repro.core.bfs_local import (BFSResult, BFSRunner, LocalGraph,
                                  MSBFSResult, MultiSourceBFSRunner,
                                  bfs_oracle, bfs_reference,
                                  build_local_graph, count_traversed_edges,
                                  msbfs_reference)
from repro.core.partition import PartitionedGraph, partition_graph
from repro.core.scheduler import PULL, PUSH, SchedulerConfig, choose_mode

__all__ = [
    "bitmap", "BFSResult", "BFSRunner", "LocalGraph", "MSBFSResult",
    "MultiSourceBFSRunner", "bfs_oracle", "bfs_reference",
    "build_local_graph", "count_traversed_edges", "msbfs_reference",
    "PartitionedGraph",
    "partition_graph", "PULL", "PUSH", "SchedulerConfig", "choose_mode",
]
