"""HBM-reader kernel: paged CSR neighbor-list gather (paper §IV-D).

The FPGA HBM reader turns "read the neighbor list of vertex v" into AXI
burst commands against its pseudo-channel.  The TPU-native translation is a
*paged gather*: the edge array lives in HBM as fixed-size pages
(page = AXI burst), and a scalar-prefetched page table drives the BlockSpec
index_map so the Pallas pipeline issues one HBM->VMEM DMA per work item,
double-buffered across grid steps (decoupled access/execute).

This is the same indirection pattern as paged-attention block tables; the
page table for a BFS iteration is built in `ops.py` from the active
vertices' (start, degree) pairs.

Grid: (num_work_items,); each item copies one page to the output row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(page_ids_ref, edges_ref, out_ref):
    del page_ids_ref  # consumed by the index_map (scalar prefetch)
    out_ref[...] = edges_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_pages(edges_paged: jax.Array, page_ids: jax.Array,
                 interpret: bool = True) -> jax.Array:
    """Gather pages of the edge array: out[i] = edges_paged[page_ids[i]].

    edges_paged: int32[num_pages, page]  (edge array viewed as pages)
    page_ids:    int32[m]                (page table, scalar-prefetched)
    returns:     int32[m, page]
    """
    m = page_ids.shape[0]
    _, page = edges_paged.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[pl.BlockSpec((1, page), lambda i, pids: (pids[i], 0))],
        out_specs=pl.BlockSpec((1, page), lambda i, pids: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, page), jnp.int32),
        interpret=interpret,
    )(page_ids, edges_paged)
