"""Fused P2->P3 MS-BFS propagate kernel (paper §IV-C, batched).

The FPGA pipeline streams whole 256/512-bit frontier words per HBM beat:
P2 reads the packed source-mask word of each gathered edge's endpoint, P3
ORs it into the candidate word of the edge's target and commits
``next |= cand & ~visited`` — the plane state never exists in unpacked
(one-byte-per-bit) form.  This kernel is the TPU analogue for the MS-BFS
engines: one pass over the budgeted edge list that

    cand[tgt[e]] |= frontier[src[e]]          (gather + scatter-OR, P2)
    new           = cand & ~seen              (P3 result writing)
    seen'         = seen | new
    count        += popcount(new)             (Scheduler stats, for free)

with no ``unpack_rows``, no ``[budget, B]`` bool message array and no
``[n_pad+1, nb]`` bool scatter buffer — the uint32 plane words are the only
currency (the win GraphScale/ScalaBFS get from packed BRAM bitmaps).

Two layouts share the kernel body structure:

* ``msbfs_propagate_planes`` — the whole-VMEM variant: the edge index
  arrays are scalar-prefetched (SMEM, like the paged-gather page table);
  the frontier/seen/candidate plane arrays live whole in VMEM across the
  1-D grid over edge chunks (the output BlockSpecs map every grid step to
  block (0, 0), so the accumulator persists between steps on TPU's
  sequential grid).  Each chunk runs a fori_loop of read-modify-write row
  updates — the per-edge loop is the literal analogue of the PE's
  one-edge-per-cycle P2 stage.  The last grid step applies P3 in place.
  VMEM bound: 4 plane arrays of (n_rows+1) * nw words (~1 MB at |V|=64k,
  B=32), so it dies around |V|≈64k–1M depending on the batch.

* ``msbfs_propagate_planes_tiled`` — the row-partitioned variant for
  HBM-scale graphs (the software analogue of ScalaBFS's 32 pseudo-
  channels each feeding the PEs only their own vertex partition).  Vertex
  rows are cut into VMEM-sized tiles; the caller pre-buckets the budgeted
  edge list by target tile (``ops._bucket_edges_by_tile``) and pre-gathers
  each edge's frontier word into a message stream, so the kernel never
  holds the frontier: per grid step it sees ONE seen/candidate tile plus
  one ``block_edges``-sized slice of that tile's message segment.  The
  ``chunk_tile`` scalar-prefetch array drives the BlockSpec index_maps —
  consecutive chunks of the same tile revisit the same output block, so
  the candidate accumulator persists across a tile's chunk run exactly
  like the whole-VMEM grid, while Pallas's pipeline double-buffers the
  streamed message chunks against it.  P3 fires once per tile, at its
  last chunk.

Under the interpret emulator (the CPU CI story) both kernels swap the
per-edge RMW loop for a one-call vectorized chunk scatter with identical
semantics (``_chunk_scatter``) — the emulator traces every loop
iteration, which serializes graph500-class edge streams into minutes;
the sequential loop remains the compiled-TPU body (force either with
``vector_scatter=``).

The pure-jnp oracle with identical semantics is
``repro.core.bitmap._scatter_or_rows`` (see ``kernels.ref``); callers
invoke these through ``repro.kernels.ops.msbfs_propagate`` /
``ops.msbfs_propagate_msgs``, which append pad rows, bucket the edge
list and auto-select the variant by plane-array footprint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Cross-plane merge ops for the scatter accumulation (the vertex-program
# ``combine``).  "or" is the bit-plane merge every shipped program uses;
# "max" is the payload-plane hook (e.g. per-plane uint32 priorities) —
# identical to "or" on single-bit planes, different on multi-bit words.
# Both accumulate from the same zero identity, and P3 keeps bitmask
# semantics (new = cand & ~seen) either way.
_COMBINE = {
    "or": lambda a, b: a | b,
    "max": jnp.maximum,
}


def _chunk_scatter(acc, rows, msgs, op: str):
    """Vectorized scatter-combine of one edge chunk (interpret mode).

    The per-edge RMW fori_loop is the TPU story — one edge per cycle
    through a resident VMEM tile, the literal P2 stage.  Under the
    interpret emulator every iteration becomes a traced dynamic-slice
    triple, so a 16M-edge pull level at rmat20 scale serializes into
    minutes of emulation.  jnp has one-call equivalents with identical
    semantics (duplicate rows combine, OOR rows drop): the bit-plane
    decomposed scatter of the ``bitmap._scatter_or_rows`` oracle for
    "or", ``at[].max`` directly for "max" — interpret mode runs those.
    """
    rows = jnp.where(rows < 0, acc.shape[0], rows)   # drop, never wrap
    if op == "max":
        return acc.at[rows].max(msgs, mode="drop")
    from repro.core import bitmap    # deferred: core imports the kernels
    return bitmap._scatter_or_rows(acc, rows, msgs)


def _kernel(src_ref, tgt_ref, frontier_ref, seen_ref, new_ref, vout_ref,
            cnt_ref, *, block_edges: int, op: str, vector_scatter: bool):
    combine = _COMBINE[op]
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        new_ref[...] = jnp.zeros_like(new_ref[...])

    base = step * block_edges

    if vector_scatter:
        s = pl.load(src_ref, (pl.ds(base, block_edges),))
        t = pl.load(tgt_ref, (pl.ds(base, block_edges),))
        new_ref[...] = _chunk_scatter(new_ref[...], t,
                                      frontier_ref[...][s], op)
    else:
        def body(i, carry):
            e = base + i
            s = src_ref[e]
            t = tgt_ref[e]
            msg = pl.load(frontier_ref, (pl.ds(s, 1), slice(None)))
            cur = pl.load(new_ref, (pl.ds(t, 1), slice(None)))
            pl.store(new_ref, (pl.ds(t, 1), slice(None)), combine(cur, msg))
            return carry

        jax.lax.fori_loop(0, block_edges, body, 0)

    @pl.when(step == pl.num_programs(0) - 1)
    def _p3():
        cand = new_ref[...]
        seen = seen_ref[...]
        nf = cand & ~seen
        new_ref[...] = nf
        vout_ref[...] = seen | nf
        cnt_ref[0, 0] = jnp.sum(jax.lax.population_count(nf)
                                .astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("block_edges", "interpret", "op",
                                    "vector_scatter"))
def msbfs_propagate_planes(frontier: jax.Array, seen: jax.Array,
                           src: jax.Array, tgt: jax.Array,
                           block_edges: int = 1024, interpret: bool = True,
                           op: str = "or",
                           vector_scatter: bool | None = None):
    """Fused gather/scatter-combine/P3 over packed plane words.

    frontier/seen: uint32[n_rows, nw] — the caller appends a trash row
        (frontier trash = 0, seen trash = all-ones) so invalid edges can
        point at row ``n_rows - 1`` and contribute nothing to the count.
    src/tgt: int32[m] in [0, n_rows), m a multiple of ``block_edges``.
    op: cross-plane merge for the scatter accumulation ("or" | "max").
    vector_scatter: None (default) = vectorize the chunk scatter exactly
        when interpreting (see :func:`_chunk_scatter`); pass True/False
        to force either body.

    Returns (new, seen_out, count[1, 1]) where
    new = scatter_combine(frontier[src] -> tgt) & ~seen,
    seen_out = seen | new, count = popcount(new).
    """
    if op not in _COMBINE:
        raise ValueError(f"op must be one of {sorted(_COMBINE)}, got {op!r}")
    if vector_scatter is None:
        vector_scatter = interpret
    n_rows, nw = frontier.shape
    m = src.shape[0]
    assert m % block_edges == 0, (m, block_edges)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // block_edges,),
        in_specs=[
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, s, t: (0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_edges=block_edges, op=op,
                          vector_scatter=vector_scatter),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((n_rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(src, tgt, frontier, seen)


def _tiled_kernel(chunk_tile_ref, tgt_ref, seen_ref, msg_ref, new_ref,
                  vout_ref, cnt_ref, *, block_edges: int, tile_rows: int,
                  op: str, vector_scatter: bool):
    """One grid step = one edge chunk of one row tile.

    ``chunk_tile_ref`` (SMEM) names the tile each chunk belongs to; it is
    nondecreasing, so a tile's chunks are a contiguous grid run and the
    candidate block (``new_ref``) persists across that run.  The first
    chunk of a run zeroes the accumulator, the last applies P3 for the
    whole tile — between them only the message chunk changes, which is
    what the Pallas pipeline double-buffers against the resident tile.
    """
    combine = _COMBINE[op]
    step = pl.program_id(0)
    nsteps = pl.num_programs(0)
    tile = chunk_tile_ref[step]
    prev_tile = chunk_tile_ref[jnp.maximum(step - 1, 0)]
    next_tile = chunk_tile_ref[jnp.minimum(step + 1, nsteps - 1)]
    is_first = (step == 0) | (tile != prev_tile)
    is_last = (step == nsteps - 1) | (tile != next_tile)

    @pl.when(step == 0)
    def _init_cnt():
        cnt_ref[0, 0] = 0

    @pl.when(is_first)
    def _init_tile():
        new_ref[...] = jnp.zeros_like(new_ref[...])

    base = step * block_edges
    row0 = tile * tile_rows

    if vector_scatter:
        t = pl.load(tgt_ref, (pl.ds(base, block_edges),)) - row0
        new_ref[...] = _chunk_scatter(new_ref[...], t, msg_ref[...], op)
    else:
        def body(i, carry):
            t = tgt_ref[base + i] - row0      # tile-local target row
            msg = pl.load(msg_ref, (pl.ds(i, 1), slice(None)))
            cur = pl.load(new_ref, (pl.ds(t, 1), slice(None)))
            pl.store(new_ref, (pl.ds(t, 1), slice(None)), combine(cur, msg))
            return carry

        jax.lax.fori_loop(0, block_edges, body, 0)

    @pl.when(is_last)
    def _p3():
        cand = new_ref[...]
        seen = seen_ref[...]
        nf = cand & ~seen
        new_ref[...] = nf
        vout_ref[...] = seen | nf
        cnt_ref[0, 0] = cnt_ref[0, 0] + jnp.sum(
            jax.lax.population_count(nf).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("tile_rows", "block_edges",
                                             "interpret", "op",
                                             "vector_scatter"))
def msbfs_propagate_planes_tiled(seen: jax.Array, msg: jax.Array,
                                 tgt: jax.Array, chunk_tile: jax.Array,
                                 tile_rows: int, block_edges: int = 1024,
                                 interpret: bool = True, op: str = "or",
                                 vector_scatter: bool | None = None):
    """Row-tiled fused scatter-combine/P3 over pre-gathered messages.

    seen: uint32[R, nw] packed plane words, R a multiple of ``tile_rows``
        (pad rows must be all-ones so they never count as discoveries).
    msg: uint32[L, nw] message stream, L = NC * block_edges — edge e's
        frontier word, already gathered and bucketed so chunk c holds only
        edges of tile ``chunk_tile[c]`` (pad slots carry msg = 0, the
        combine identity for both "or" and "max").
    tgt: int32[L] GLOBAL target rows; tgt[e] must lie inside chunk
        e // block_edges's tile (pad slots point at the tile's first row).
    chunk_tile: int32[NC] nondecreasing tile id per chunk, covering every
        tile of ``seen`` at least once (empty tiles get one pad chunk so
        their P3 still runs).
    vector_scatter: None (default) = vectorize the chunk scatter exactly
        when interpreting (see :func:`_chunk_scatter`).

    Returns (new, seen_out, count[1, 1]) with semantics identical to
    ``msbfs_propagate_planes`` restricted to the streamed edges.
    """
    if op not in _COMBINE:
        raise ValueError(f"op must be one of {sorted(_COMBINE)}, got {op!r}")
    if vector_scatter is None:
        vector_scatter = interpret
    n_rows, nw = seen.shape
    assert n_rows % tile_rows == 0, (n_rows, tile_rows)
    num_chunks = chunk_tile.shape[0]
    assert msg.shape[0] == num_chunks * block_edges, (
        msg.shape, num_chunks, block_edges)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_chunks,),
        in_specs=[
            pl.BlockSpec((tile_rows, nw), lambda i, ct, t: (ct[i], 0)),
            pl.BlockSpec((block_edges, nw), lambda i, ct, t: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_rows, nw), lambda i, ct, t: (ct[i], 0)),
            pl.BlockSpec((tile_rows, nw), lambda i, ct, t: (ct[i], 0)),
            pl.BlockSpec((1, 1), lambda i, ct, t: (0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_tiled_kernel, block_edges=block_edges,
                          tile_rows=tile_rows, op=op,
                          vector_scatter=vector_scatter),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((n_rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(chunk_tile, tgt, seen, msg)
