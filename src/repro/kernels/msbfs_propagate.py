"""Fused P2->P3 MS-BFS propagate kernel (paper §IV-C, batched).

The FPGA pipeline streams whole 256/512-bit frontier words per HBM beat:
P2 reads the packed source-mask word of each gathered edge's endpoint, P3
ORs it into the candidate word of the edge's target and commits
``next |= cand & ~visited`` — the plane state never exists in unpacked
(one-byte-per-bit) form.  This kernel is the TPU analogue for the MS-BFS
engines: one pass over the budgeted edge list that

    cand[tgt[e]] |= frontier[src[e]]          (gather + scatter-OR, P2)
    new           = cand & ~seen              (P3 result writing)
    seen'         = seen | new
    count        += popcount(new)             (Scheduler stats, for free)

with no ``unpack_rows``, no ``[budget, B]`` bool message array and no
``[n_pad+1, nb]`` bool scatter buffer — the uint32 plane words are the only
currency (the win GraphScale/ScalaBFS get from packed BRAM bitmaps).

Layout: the edge index arrays are scalar-prefetched (SMEM, like the
paged-gather page table); the frontier/seen/candidate plane arrays live
whole in VMEM across the 1-D grid over edge chunks (the output BlockSpecs
map every grid step to block (0, 0), so the accumulator persists between
steps on TPU's sequential grid).  Each chunk runs a fori_loop of
read-modify-write row updates — the per-edge loop is the literal analogue
of the PE's one-edge-per-cycle P2 stage.  The last grid step applies P3 in
place.  VMEM bound: 4 plane arrays of (n_rows+1) * nw words (~1 MB at
|V|=64k, B=32); larger graphs need a row-partitioned variant.

The pure-jnp oracle with identical semantics is
``repro.core.bitmap._scatter_or_rows`` (see ``kernels.ref``); callers
invoke this through ``repro.kernels.ops.msbfs_propagate``, which appends
the trash row and pads the edge list.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Cross-plane merge ops for the scatter accumulation (the vertex-program
# ``combine``).  "or" is the bit-plane merge every shipped program uses;
# "max" is the payload-plane hook (e.g. per-plane uint32 priorities) —
# identical to "or" on single-bit planes, different on multi-bit words.
# Both accumulate from the same zero identity, and P3 keeps bitmask
# semantics (new = cand & ~seen) either way.
_COMBINE = {
    "or": lambda a, b: a | b,
    "max": jnp.maximum,
}


def _kernel(src_ref, tgt_ref, frontier_ref, seen_ref, new_ref, vout_ref,
            cnt_ref, *, block_edges: int, op: str):
    combine = _COMBINE[op]
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        new_ref[...] = jnp.zeros_like(new_ref[...])

    base = step * block_edges

    def body(i, carry):
        e = base + i
        s = src_ref[e]
        t = tgt_ref[e]
        msg = pl.load(frontier_ref, (pl.ds(s, 1), slice(None)))
        cur = pl.load(new_ref, (pl.ds(t, 1), slice(None)))
        pl.store(new_ref, (pl.ds(t, 1), slice(None)), combine(cur, msg))
        return carry

    jax.lax.fori_loop(0, block_edges, body, 0)

    @pl.when(step == pl.num_programs(0) - 1)
    def _p3():
        cand = new_ref[...]
        seen = seen_ref[...]
        nf = cand & ~seen
        new_ref[...] = nf
        vout_ref[...] = seen | nf
        cnt_ref[0, 0] = jnp.sum(jax.lax.population_count(nf)
                                .astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("block_edges", "interpret", "op"))
def msbfs_propagate_planes(frontier: jax.Array, seen: jax.Array,
                           src: jax.Array, tgt: jax.Array,
                           block_edges: int = 1024, interpret: bool = True,
                           op: str = "or"):
    """Fused gather/scatter-combine/P3 over packed plane words.

    frontier/seen: uint32[n_rows, nw] — the caller appends a trash row
        (frontier trash = 0, seen trash = all-ones) so invalid edges can
        point at row ``n_rows - 1`` and contribute nothing to the count.
    src/tgt: int32[m] in [0, n_rows), m a multiple of ``block_edges``.
    op: cross-plane merge for the scatter accumulation ("or" | "max").

    Returns (new, seen_out, count[1, 1]) where
    new = scatter_combine(frontier[src] -> tgt) & ~seen,
    seen_out = seen | new, count = popcount(new).
    """
    if op not in _COMBINE:
        raise ValueError(f"op must be one of {sorted(_COMBINE)}, got {op!r}")
    n_rows, nw = frontier.shape
    m = src.shape[0]
    assert m % block_edges == 0, (m, block_edges)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // block_edges,),
        in_specs=[
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
            pl.BlockSpec((n_rows, nw), lambda i, s, t: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, s, t: (0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_edges=block_edges, op=op),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((n_rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(src, tgt, frontier, seen)
