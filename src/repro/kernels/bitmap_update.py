"""Fused P3 bitmap-update kernel (paper §IV-C "Result Writing").

The FPGA's P3 stage writes three structures per accepted vertex: the next
frontier bit, the visited bit, and the level value.  The TPU analogue is an
elementwise fused pass over packed uint32 words held in VMEM:

    new_frontier = candidates & ~visited
    visited'     = visited | new_frontier
    count       += popcount(new_frontier)        (frontier size for the
                                                  Scheduler's mode decision)

Fusing the three ops keeps each word's round trip HBM->VMEM->HBM to a single
pass (the "double pump BRAM: two ops per cycle" analogue), and the popcount
rides along for free instead of a second reduction pass.

Grid: 1-D over row-tiles of a [rows, 128] word array; BlockSpec keeps
(block_rows, 128) word tiles in VMEM (8 KiB at block_rows=16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cand_ref, vis_ref, nf_ref, vout_ref, cnt_ref):
    cand = cand_ref[...]
    vis = vis_ref[...]
    nf = cand & ~vis
    nf_ref[...] = nf
    vout_ref[...] = vis | nf

    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[0, 0] = 0

    cnt_ref[0, 0] += jnp.sum(
        jax.lax.population_count(nf).astype(jnp.int32))


def _kernel_batch(cand_ref, vis_ref, nf_ref, vout_ref, cnt_ref):
    cand = cand_ref[...]
    vis = vis_ref[...]
    nf = cand & ~vis
    nf_ref[...] = nf
    vout_ref[...] = vis | nf

    @pl.when(pl.program_id(1) == 0)
    def _init():
        cnt_ref[0, 0, 0] = 0

    cnt_ref[0, 0, 0] += jnp.sum(
        jax.lax.population_count(nf).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bitmap_update_batch(cand: jax.Array, visited: jax.Array,
                        block_rows: int = 16, interpret: bool = True):
    """Fused frontier update over a BATCH of bit-planes.

    cand/visited: uint32[batch, rows, 128] — one plane per 32-source word of
    an MS-BFS batch (or any stack of frontiers sharing a P3 pass).  The grid
    walks (plane, row-tile); each plane's new-bit popcount accumulates into
    its own counter, so the per-source-group discovery counts the Scheduler
    wants ride along for free, exactly like the single-frontier kernel.

    Returns (new_frontier, visited_out, new_counts[batch, 1, 1]).
    """
    b, rows, cols = cand.shape
    assert cols == 128 and rows % block_rows == 0, (b, rows, cols)
    grid = (b, rows // block_rows)
    blk = pl.BlockSpec((1, block_rows, 128), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        _kernel_batch,
        grid=grid,
        in_specs=[blk, blk],
        out_specs=[blk, blk,
                   pl.BlockSpec((1, 1, 1), lambda i, j: (i, 0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((b, rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((b, rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((b, 1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cand, visited)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bitmap_update(cand: jax.Array, visited: jax.Array,
                  block_rows: int = 16, interpret: bool = True):
    """Fused frontier update on uint32[rows, 128] word arrays.

    Returns (new_frontier, visited_out, new_count).
    """
    rows, cols = cand.shape
    assert cols == 128 and rows % block_rows == 0, (rows, cols)
    grid = (rows // block_rows,)
    blk = pl.BlockSpec((block_rows, 128), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[blk, blk],
        out_specs=[blk, blk, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cand, visited)
