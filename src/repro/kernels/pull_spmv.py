"""MXU pull-mode kernel: block-sparse boolean SpMV (beyond-paper, TPU co-design).

Pull-mode BFS is `cand = (A_csc ⊗or.and frontier) ∧ ¬visited` — a boolean
SpMV.  The FPGA streams CSC lists; a TPU has a 128×128 systolic MXU, so for
the *dense hub blocks* of a scale-free graph we store 0/1 adjacency tiles in
bf16 and evaluate the boolean product as a masked matmul:

    out[r] = Σ_c  A_block[r, c] @ f[c]          (f32 accumulate, >0 == OR)

The frontier operand is [block, lanes]: lanes > 1 batches multiple BFS
sources (multi-source BFS), which is what fills the MXU; a single-source
traversal uses lane 0 only.

Blocks arrive sorted by output row; `row_start` flags (scalar-prefetched)
reset the accumulator on the first block of each row, so each output tile is
revisited consecutively across grid steps (sequential-grid accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(brow_ref, bcol_ref, first_ref, blocks_ref, f_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(first_ref[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = blocks_ref[0]                    # [B, B] bf16 0/1 tile
    f = f_ref[0]                         # [B, L] bf16 frontier lanes
    out_ref[0] += jax.lax.dot_general(
        a, f, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_row_blocks", "interpret"))
def pull_spmv_blocks(blocks: jax.Array, block_row: jax.Array,
                     block_col: jax.Array, row_first: jax.Array,
                     frontier: jax.Array, num_row_blocks: int,
                     interpret: bool = True) -> jax.Array:
    """Block-sparse boolean SpMV on the MXU.

    blocks:    bf16[nb, B, B]   0/1 adjacency tiles (CSC orientation:
                                rows=children, cols=parents), sorted by row.
    block_row: int32[nb]        output row-block of each tile.
    block_col: int32[nb]        frontier column-block of each tile.
    row_first: int32[nb]        1 on the first tile of each row run.
    frontier:  bf16[ncb, B, L]  frontier lanes per column block.
    returns:   f32[num_row_blocks, B, L]; OR == (out > 0).
    """
    nb, b, _ = blocks.shape
    _, _, lanes = frontier.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, b, b), lambda i, br, bc, fs: (i, 0, 0)),
            pl.BlockSpec((1, b, lanes), lambda i, br, bc, fs: (bc[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, lanes),
                               lambda i, br, bc, fs: (br[i], 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_row_blocks, b, lanes),
                                       jnp.float32),
        interpret=interpret,
    )(block_row, block_col, row_first, blocks, frontier)
