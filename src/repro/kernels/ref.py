"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitmap_update_ref(cand: jax.Array, visited: jax.Array):
    """Oracle for kernels.bitmap_update.bitmap_update."""
    nf = cand & ~visited
    vout = visited | nf
    cnt = jnp.sum(jax.lax.population_count(nf).astype(jnp.int32)).reshape(1, 1)
    return nf, vout, cnt


def bitmap_update_batch_ref(cand: jax.Array, visited: jax.Array):
    """Oracle for kernels.bitmap_update.bitmap_update_batch."""
    nf = cand & ~visited
    vout = visited | nf
    cnt = jnp.sum(jax.lax.population_count(nf).astype(jnp.int32),
                  axis=(1, 2)).reshape(-1, 1, 1)
    return nf, vout, cnt


def msbfs_propagate_planes_ref(frontier: jax.Array, seen: jax.Array,
                               src: jax.Array, tgt: jax.Array,
                               op: str = "or"):
    """Oracle for kernels.msbfs_propagate.msbfs_propagate_planes.

    Same padded-input contract as the kernel (trash row appended by the
    ops wrapper); the "or" scatter is the per-bit-plane jnp fallback
    ``bitmap._scatter_or_rows``, the "max" scatter is a segment-max over
    the same zero identity — the kernel must agree bit for bit with both.
    P3 keeps bitmask semantics (new = cand & ~seen) for every op.
    """
    if op == "or":
        from repro.core.bitmap import _scatter_or_rows
        cand = _scatter_or_rows(jnp.zeros_like(frontier), tgt,
                                frontier[src])
    elif op == "max":
        cand = jnp.zeros_like(frontier).at[tgt].max(frontier[src],
                                                    mode="drop")
    else:
        raise ValueError(f"op must be 'or' or 'max', got {op!r}")
    nf = cand & ~seen
    cnt = jnp.sum(jax.lax.population_count(nf).astype(jnp.int32)
                  ).reshape(1, 1)
    return nf, seen | nf, cnt


def msbfs_propagate_msgs_ref(seen: jax.Array, msg: jax.Array,
                             tgt: jax.Array, valid: jax.Array,
                             op: str = "or"):
    """Oracle for kernels.ops.msbfs_propagate_msgs (msgs-form tiled path).

    Unpadded semantics: scatter-combine ``msg[e]`` into row ``tgt[e]``
    for every valid in-range edge, then P3.  The tiled kernel's bucketing
    and pad rows/slots must be invisible against this.
    """
    n = seen.shape[0]
    ok = valid & (tgt >= 0) & (tgt < n)
    msg = jnp.where(ok[:, None], msg, jnp.uint32(0))
    tgt = jnp.where(ok, tgt, n)
    if op == "or":
        from repro.core.bitmap import _scatter_or_rows
        cand = _scatter_or_rows(jnp.zeros_like(seen), tgt, msg)
    elif op == "max":
        cand = jnp.zeros_like(seen).at[tgt].max(msg, mode="drop")
    else:
        raise ValueError(f"op must be 'or' or 'max', got {op!r}")
    nf = cand & ~seen
    cnt = jnp.sum(jax.lax.population_count(nf).astype(jnp.int32))
    return nf, seen | nf, cnt


def gather_pages_ref(edges_paged: jax.Array, page_ids: jax.Array):
    """Oracle for kernels.csr_gather.gather_pages."""
    return edges_paged[page_ids]


def pull_spmv_blocks_ref(blocks: jax.Array, block_row: jax.Array,
                         block_col: jax.Array, row_first: jax.Array,
                         frontier: jax.Array, num_row_blocks: int):
    """Oracle for kernels.pull_spmv.pull_spmv_blocks."""
    del row_first
    nb, b, _ = blocks.shape
    lanes = frontier.shape[-1]
    out = jnp.zeros((num_row_blocks, b, lanes), jnp.float32)
    prod = jnp.einsum("nij,njl->nil", blocks.astype(jnp.float32),
                      frontier[block_col].astype(jnp.float32))
    return out.at[block_row].add(prod)


def flash_attention_ref(q, k, v, *, causal=True):
    """Oracle for kernels.flash_attention: plain softmax attention.

    q/k/v: [BH, S, hd] -> [BH, S, hd]."""
    import numpy as np
    scale = 1.0 / float(np.sqrt(q.shape[-1]))
    s_ = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, k.shape[1]), bool))
        s_ = jnp.where(mask[None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
