"""Jit'd wrappers that connect the Pallas kernels to the BFS engine.

``interpret=True`` everywhere in this container (CPU); on a real TPU the
same calls run compiled (set REPRO_PALLAS_INTERPRET=0).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitmap_update import bitmap_update, bitmap_update_batch
from repro.kernels.csr_gather import gather_pages
from repro.kernels.msbfs_propagate import (msbfs_propagate_planes,
                                           msbfs_propagate_planes_tiled)
from repro.kernels.pull_spmv import pull_spmv_blocks

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

# VMEM budget for one propagate call's plane working set.  The whole-VMEM
# kernel keeps 4 plane arrays resident (frontier/seen/new/vout); above this
# budget ``msbfs_propagate`` switches to the row-tiled kernel.  ~2 MiB
# leaves headroom (of TPU's ~16 MiB VMEM) for the double-buffered message
# stream and the scalar-prefetch arrays.
PROPAGATE_VMEM_BYTES = int(os.environ.get("REPRO_PROPAGATE_VMEM_BYTES",
                                          2 * 1024 * 1024))


def _plane_footprint_bytes(n_rows: int, nw: int) -> int:
    """Whole-VMEM kernel working set: 4 plane arrays incl. the trash row."""
    return 4 * (n_rows + 1) * nw * 4


def _auto_tile_rows(nw: int, vmem_bytes: int) -> int:
    """Tile-size rule: the tiled kernel holds ~8 row-tile-sized buffers
    (seen + new + vout tiles, their pipeline double-buffers, and slack for
    the streamed message chunks), so budget 32*nw bytes per row and round
    down to the 8-row sublane multiple (int32 min tile is (8, 128))."""
    return max((vmem_bytes // (32 * nw)) // 8 * 8, 8)


def _auto_block_edges(m: int, nw: int, vmem_bytes: int | None = None) -> int:
    """Edge-chunk length for the streamed message blocks.

    Two pressures.  The grid runs one step per chunk, so a fixed
    1024-edge chunk at graph500-class budgets (m ~ 16M edges per pull
    level on rmat20) means tens of thousands of grid steps — pure
    pipeline overhead, and interpret mode inlines every step at trace
    time.  The chunk therefore grows with m, targeting <= 256 real-edge
    steps.  Against that, one streamed msg block (block_edges * nw * 4
    bytes) must stay a small fraction (1/8) of the VMEM budget so it can
    double-buffer beside the resident plane tiles.  Always a multiple of
    the 1024 floor, so sub-1024 budgets share one compiled shape."""
    vmem = PROPAGATE_VMEM_BYTES if vmem_bytes is None else vmem_bytes
    cap = max((vmem // (8 * 4 * nw)) // 1024 * 1024, 1024)
    need = -(-(-(-m // 256)) // 1024) * 1024
    return int(min(max(need, 1024), cap))


def propagate_plan(n_rows: int, nw: int, tile_rows: int | None = None,
                   vmem_bytes: int | None = None) -> dict:
    """Whole-VMEM vs row-tiled selection for ``msbfs_propagate``.

    ``tile_rows``: None = auto (tile iff the 4-plane footprint exceeds the
    VMEM budget), 0 = force whole-VMEM, > 0 = force tiling at that size.
    Returns dict(tiled, tile_rows, num_tiles, footprint_bytes).
    """
    vmem = PROPAGATE_VMEM_BYTES if vmem_bytes is None else vmem_bytes
    fp = _plane_footprint_bytes(n_rows, nw)
    if tile_rows == 0 or (tile_rows is None and fp <= vmem):
        return dict(tiled=False, tile_rows=0, num_tiles=1,
                    footprint_bytes=fp)
    if tile_rows is None:
        tile_rows = _auto_tile_rows(nw, vmem)
    tile_rows = int(tile_rows)
    if tile_rows < 1:
        raise ValueError(f"tile_rows must be >= 1, got {tile_rows}")
    return dict(tiled=True, tile_rows=tile_rows,
                num_tiles=-(-n_rows // tile_rows), footprint_bytes=fp)


def _bucket_edges_by_tile(msg: jax.Array, tgt: jax.Array, ok: jax.Array,
                          num_tiles: int, tile_rows: int, block_edges: int):
    """Bucket a budgeted edge list by target row tile (jnp, jit-static).

    Builds the streamed inputs of ``msbfs_propagate_planes_tiled``: a
    stable sort groups edges by ``tgt // tile_rows``, each tile's bucket is
    cut into ``block_edges``-sized chunks, and the chunks are laid out
    tile-major so ``chunk_tile`` is nondecreasing (the kernel's
    accumulator-persistence invariant).  Degree-aware budget tiling falls
    out of the counting: chunk capacity is allocated per tile from the
    ACTUAL bucket sizes, so a hub vertex whose in-edges concentrate on one
    tile simply gets more chunks there — the total stays within the static
    ceil(m / C) + T bound (each tile wastes at most one partial chunk, and
    empty tiles get one pad chunk so their P3 still fires).

    msg: uint32[m, nw] pre-gathered frontier words (invalid slots zeroed).
    tgt: int32[m] global target rows; ``ok`` False slots are dropped.
    Returns (stream_msg uint32[L, nw], stream_tgt int32[L],
    chunk_tile int32[NC]) with L = NC * block_edges; pad slots carry
    msg = 0 aimed at their chunk's tile base row (a combine no-op).
    """
    m, nw = msg.shape
    t_, c_ = num_tiles, block_edges
    num_chunks = -(-m // c_) + t_
    l_ = num_chunks * c_
    tile = jnp.where(ok, tgt // tile_rows, t_).astype(jnp.int32)
    order = jnp.argsort(tile)                      # stable in jax
    tile_s = tile[order]
    counts = jnp.bincount(tile, length=t_ + 1).astype(jnp.int32)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(m, dtype=jnp.int32) - seg_start[tile_s]
    chunks_per_tile = jnp.maximum(-(-counts[:t_] // c_), 1)
    chunk_off = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(chunks_per_tile)[:-1]])
    pos = jnp.where(tile_s < t_,
                    chunk_off[jnp.minimum(tile_s, t_ - 1)] * c_ + rank,
                    l_).astype(jnp.int32)
    # tile id per chunk; trailing unused chunks ride the last tile so the
    # sequence stays nondecreasing and the last tile's P3 stays last
    chunk_tile = jnp.searchsorted(
        jnp.cumsum(chunks_per_tile), jnp.arange(num_chunks, dtype=jnp.int32),
        side="right").astype(jnp.int32)
    chunk_tile = jnp.minimum(chunk_tile, t_ - 1)
    stream_msg = jnp.zeros((l_, nw), jnp.uint32).at[pos].set(
        msg[order], mode="drop")
    default_tgt = chunk_tile[jnp.arange(l_) // c_] * tile_rows
    stream_tgt = default_tgt.at[pos].set(
        jnp.where(ok, tgt, 0).astype(jnp.int32)[order], mode="drop")
    return stream_msg, stream_tgt, chunk_tile


def _propagate_tiled(seen_w: jax.Array, msg: jax.Array, tgt: jax.Array,
                     ok: jax.Array, tile_rows: int, block_edges: int,
                     interpret: bool, op: str):
    """Shared tiled-path tail: pad rows to a tile multiple, bucket, run."""
    n, nw = seen_w.shape
    t_ = -(-n // tile_rows)
    r_ = t_ * tile_rows
    if r_ > n:
        # pad rows: seen all-ones, so stray writes never count as
        # discoveries (the tiled path's analogue of the trash row)
        seen_w = jnp.concatenate(
            [seen_w, jnp.full((r_ - n, nw), 0xFFFFFFFF, jnp.uint32)])
    sm, st, ct = _bucket_edges_by_tile(msg, tgt, ok, t_, tile_rows,
                                       block_edges)
    new, vout, cnt = msbfs_propagate_planes_tiled(
        seen_w, sm, st, ct, tile_rows=tile_rows, block_edges=block_edges,
        interpret=interpret, op=op)
    return new[:n], vout[:n], cnt[0, 0]


def msbfs_propagate(frontier_w: jax.Array, seen_w: jax.Array,
                    src: jax.Array, tgt: jax.Array, valid: jax.Array,
                    block_edges: int | None = None,
                    interpret: bool | None = None,
                    op: str = "or", tile_rows: int | None = None):
    """Fused P2->P3 vertex-program propagate: gather ``frontier_w[src]``
    words and scatter-combine them into the candidate planes at ``tgt``
    (``op``: "or" for bit-planes, "max" for payload planes), then commit
    ``new = cand & ~seen`` / ``seen |= new`` in the same kernel pass.

    frontier_w/seen_w: uint32[n_pad, nw] packed plane words.
    src/tgt: int32[m] edge endpoints; slots with ``valid`` False (or any
    out-of-range index) are dropped.  ``tile_rows`` picks the kernel
    variant (see :func:`propagate_plan`): by default graphs whose 4-plane
    working set exceeds ``PROPAGATE_VMEM_BYTES`` run the row-tiled kernel.
    ``block_edges`` (None = auto, :func:`_auto_block_edges`) is the
    streamed edge-chunk length — one grid step each.
    Returns (new, seen_out, new_count).
    """
    if interpret is None:
        interpret = INTERPRET
    n, nw = frontier_w.shape
    m = src.shape[0]
    if m == 0:
        new = jnp.zeros_like(frontier_w)
        return new, seen_w, jnp.int32(0)
    if block_edges is None:
        block_edges = _auto_block_edges(m, nw)
    ok = valid & (src >= 0) & (src < n) & (tgt >= 0) & (tgt < n)
    plan = propagate_plan(n, nw, tile_rows)
    if plan["tiled"]:
        # pre-gather the messages (an XLA HBM gather): the tiled kernel
        # streams them per tile and never holds the frontier in VMEM
        msg = jnp.where(ok[:, None], frontier_w[jnp.maximum(src, 0)],
                        jnp.uint32(0))
        return _propagate_tiled(seen_w, msg, tgt, ok, plan["tile_rows"],
                                block_edges, interpret, op)
    # trash row n: zero frontier mask (contributes nothing), all-ones seen
    # (so the trash candidates never count as discoveries)
    f1 = jnp.concatenate([frontier_w, jnp.zeros((1, nw), jnp.uint32)])
    s1 = jnp.concatenate(
        [seen_w, jnp.full((1, nw), 0xFFFFFFFF, jnp.uint32)])
    sidx = jnp.where(ok, src, n).astype(jnp.int32)
    tidx = jnp.where(ok, tgt, n).astype(jnp.int32)
    # always pad m up to whole ``block_edges`` chunks: baking a raw small
    # m into the static block size compiled a fresh pallas_call per
    # distinct tiny budget
    pad = (-m) % block_edges
    if pad:
        sidx = jnp.pad(sidx, (0, pad), constant_values=n)
        tidx = jnp.pad(tidx, (0, pad), constant_values=n)
    new, vout, cnt = msbfs_propagate_planes(f1, s1, sidx, tidx,
                                            block_edges=block_edges,
                                            interpret=interpret, op=op)
    return new[:-1], vout[:-1], cnt[0, 0]


def msbfs_propagate_msgs(seen_w: jax.Array, msg: jax.Array, tgt: jax.Array,
                         valid: jax.Array, tile_rows: int | None = None,
                         block_edges: int | None = None,
                         interpret: bool | None = None, op: str = "or"):
    """Msgs-form fused propagate: like :func:`msbfs_propagate` but with the
    frontier gather already done — ``msg[e]`` is the packed plane word edge
    ``e`` carries into row ``tgt[e]``.  This is the natural entry when the
    gather happens under a different sharding than the scatter (the
    distributed pull path gathers from the all-gathered global frontier
    but scatters into shard-local rows).  Always runs the row-tiled
    kernel; ``tile_rows`` defaults to the auto rule of
    :func:`propagate_plan`.  Returns (new, seen_out, new_count).
    """
    if interpret is None:
        interpret = INTERPRET
    n, nw = seen_w.shape
    m = tgt.shape[0]
    if m == 0:
        new = jnp.zeros_like(seen_w)
        return new, seen_w, jnp.int32(0)
    if block_edges is None:
        block_edges = _auto_block_edges(m, nw)
    if tile_rows is None:
        tile_rows = min(_auto_tile_rows(nw, PROPAGATE_VMEM_BYTES), n)
    tile_rows = int(tile_rows)
    if tile_rows < 1:
        raise ValueError(f"tile_rows must be >= 1, got {tile_rows}")
    ok = valid & (tgt >= 0) & (tgt < n)
    msg = jnp.where(ok[:, None], msg, jnp.uint32(0))
    return _propagate_tiled(seen_w, msg, tgt, ok, tile_rows, block_edges,
                            interpret, op)


def _pad_rows_to_block(rows: int, cap: int = 16) -> tuple[int, int]:
    """Grid plan for the row-blocked P3 kernels: ``block_rows = min(rows,
    cap)`` with ``rows`` padded up to a whole multiple.  (The old plan
    hunted for an exact divisor <= cap, which degrades to 1-row blocks —
    a ``rows``-step grid — whenever the row count is prime.)  The pad rows
    are zeros: cand 0 & ~visited contributes no new bits and no count."""
    block = min(rows, cap)
    return -(-rows // block) * block, block


def fused_frontier_update(cand_words: jax.Array, visited_words: jax.Array):
    """P3 update on flat uint32[w] words; returns (new, visited, count)."""
    w = cand_words.shape[0]
    rows = max((w + 127) // 128, 1)
    rows_pad, block_rows = _pad_rows_to_block(rows)
    pad = rows_pad * 128 - w
    c2 = jnp.pad(cand_words, (0, pad)).reshape(rows_pad, 128)
    v2 = jnp.pad(visited_words, (0, pad)).reshape(rows_pad, 128)
    nf, vo, cnt = bitmap_update(c2, v2, block_rows=block_rows,
                                interpret=INTERPRET)
    return (nf.reshape(-1)[:w], vo.reshape(-1)[:w], cnt[0, 0])


def fused_frontier_update_batch(cand_words: jax.Array,
                                visited_words: jax.Array):
    """P3 update on a stack of planes: uint32[g, w] -> (new, visited,
    counts[g]).  One fused pass per plane, per-plane popcounts riding
    along (the MS-BFS per-source-word discovery counters)."""
    g, w = cand_words.shape
    rows = max((w + 127) // 128, 1)
    rows_pad, block_rows = _pad_rows_to_block(rows)
    pad = rows_pad * 128 - w
    c2 = jnp.pad(cand_words, ((0, 0), (0, pad))).reshape(g, rows_pad, 128)
    v2 = jnp.pad(visited_words, ((0, 0), (0, pad))).reshape(g, rows_pad, 128)
    nf, vo, cnt = bitmap_update_batch(c2, v2, block_rows=block_rows,
                                      interpret=INTERPRET)
    return (nf.reshape(g, -1)[:, :w], vo.reshape(g, -1)[:, :w],
            cnt.reshape(g))


def build_page_table(starts: np.ndarray, degrees: np.ndarray, page: int,
                     budget_pages: int):
    """Host-side helper: (start, degree) pairs -> page table + masks.

    Returns (page_ids int32[budget_pages], item_vertex int32[budget_pages],
    first_offset int32[budget_pages]) where page_ids[i] is the page to fetch
    for work item i and first_offset marks the in-page start of the list.
    """
    page_ids, owner, offs = [], [], []
    for v, (s, d) in enumerate(zip(starts, degrees)):
        if d <= 0:
            continue
        p0, p1 = s // page, (s + d - 1) // page
        for p in range(p0, p1 + 1):
            page_ids.append(p)
            owner.append(v)
            offs.append(s - p * page if p == p0 else 0)
    k = len(page_ids)
    if k > budget_pages:
        raise OverflowError(f"page table {k} > budget {budget_pages}")
    pad = budget_pages - k
    return (np.asarray(page_ids + [0] * pad, np.int32),
            np.asarray(owner + [-1] * pad, np.int32),
            np.asarray(offs + [0] * pad, np.int32))


def read_neighbor_pages(edges: jax.Array, page_ids: jax.Array, page: int):
    """HBM-reader op: fetch the pages listed in ``page_ids``.

    edges is the flat int32 edge array (padded to a page multiple).
    """
    paged = edges.reshape(-1, page)
    return gather_pages(paged, page_ids, interpret=INTERPRET)


def pull_spmv(blocks, block_row, block_col, frontier, num_row_blocks: int):
    """Boolean block SpMV; returns packed OR result as bool[rb, B, L]."""
    row_first = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (block_row[1:] != block_row[:-1]).astype(jnp.int32)])
    acc = pull_spmv_blocks(blocks, block_row, block_col, row_first, frontier,
                           num_row_blocks=num_row_blocks,
                           interpret=INTERPRET)
    return acc > 0
