"""Jit'd wrappers that connect the Pallas kernels to the BFS engine.

``interpret=True`` everywhere in this container (CPU); on a real TPU the
same calls run compiled (set REPRO_PALLAS_INTERPRET=0).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitmap_update import bitmap_update, bitmap_update_batch
from repro.kernels.csr_gather import gather_pages
from repro.kernels.msbfs_propagate import msbfs_propagate_planes
from repro.kernels.pull_spmv import pull_spmv_blocks

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def msbfs_propagate(frontier_w: jax.Array, seen_w: jax.Array,
                    src: jax.Array, tgt: jax.Array, valid: jax.Array,
                    block_edges: int = 1024, interpret: bool | None = None,
                    op: str = "or"):
    """Fused P2->P3 vertex-program propagate: gather ``frontier_w[src]``
    words and scatter-combine them into the candidate planes at ``tgt``
    (``op``: "or" for bit-planes, "max" for payload planes), then commit
    ``new = cand & ~seen`` / ``seen |= new`` in the same kernel pass.

    frontier_w/seen_w: uint32[n_pad, nw] packed plane words.
    src/tgt: int32[m] edge endpoints; slots with ``valid`` False (or any
    out-of-range index) are dropped.  Returns (new, seen_out, new_count).
    """
    if interpret is None:
        interpret = INTERPRET
    n, nw = frontier_w.shape
    m = src.shape[0]
    if m == 0:
        new = jnp.zeros_like(frontier_w)
        return new, seen_w, jnp.int32(0)
    # trash row n: zero frontier mask (contributes nothing), all-ones seen
    # (so the trash candidates never count as discoveries)
    f1 = jnp.concatenate([frontier_w, jnp.zeros((1, nw), jnp.uint32)])
    s1 = jnp.concatenate(
        [seen_w, jnp.full((1, nw), 0xFFFFFFFF, jnp.uint32)])
    ok = valid & (src >= 0) & (src < n) & (tgt >= 0) & (tgt < n)
    sidx = jnp.where(ok, src, n).astype(jnp.int32)
    tidx = jnp.where(ok, tgt, n).astype(jnp.int32)
    blk = min(block_edges, m)
    pad = (-m) % blk
    if pad:
        sidx = jnp.pad(sidx, (0, pad), constant_values=n)
        tidx = jnp.pad(tidx, (0, pad), constant_values=n)
    new, vout, cnt = msbfs_propagate_planes(f1, s1, sidx, tidx,
                                            block_edges=blk,
                                            interpret=interpret, op=op)
    return new[:-1], vout[:-1], cnt[0, 0]


def fused_frontier_update(cand_words: jax.Array, visited_words: jax.Array):
    """P3 update on flat uint32[w] words; returns (new, visited, count)."""
    w = cand_words.shape[0]
    rows = max((w + 127) // 128, 1)
    pad = rows * 128 - w
    c2 = jnp.pad(cand_words, (0, pad)).reshape(rows, 128)
    v2 = jnp.pad(visited_words, (0, pad)).reshape(rows, 128)
    block_rows = _largest_divisor(rows, 16)
    nf, vo, cnt = bitmap_update(c2, v2, block_rows=block_rows,
                                interpret=INTERPRET)
    return (nf.reshape(-1)[:w], vo.reshape(-1)[:w], cnt[0, 0])


def fused_frontier_update_batch(cand_words: jax.Array,
                                visited_words: jax.Array):
    """P3 update on a stack of planes: uint32[g, w] -> (new, visited,
    counts[g]).  One fused pass per plane, per-plane popcounts riding
    along (the MS-BFS per-source-word discovery counters)."""
    g, w = cand_words.shape
    rows = max((w + 127) // 128, 1)
    pad = rows * 128 - w
    c2 = jnp.pad(cand_words, ((0, 0), (0, pad))).reshape(g, rows, 128)
    v2 = jnp.pad(visited_words, ((0, 0), (0, pad))).reshape(g, rows, 128)
    block_rows = _largest_divisor(rows, 16)
    nf, vo, cnt = bitmap_update_batch(c2, v2, block_rows=block_rows,
                                      interpret=INTERPRET)
    return (nf.reshape(g, -1)[:, :w], vo.reshape(g, -1)[:, :w],
            cnt.reshape(g))


def _largest_divisor(n: int, cap: int) -> int:
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def build_page_table(starts: np.ndarray, degrees: np.ndarray, page: int,
                     budget_pages: int):
    """Host-side helper: (start, degree) pairs -> page table + masks.

    Returns (page_ids int32[budget_pages], item_vertex int32[budget_pages],
    first_offset int32[budget_pages]) where page_ids[i] is the page to fetch
    for work item i and first_offset marks the in-page start of the list.
    """
    page_ids, owner, offs = [], [], []
    for v, (s, d) in enumerate(zip(starts, degrees)):
        if d <= 0:
            continue
        p0, p1 = s // page, (s + d - 1) // page
        for p in range(p0, p1 + 1):
            page_ids.append(p)
            owner.append(v)
            offs.append(s - p * page if p == p0 else 0)
    k = len(page_ids)
    if k > budget_pages:
        raise OverflowError(f"page table {k} > budget {budget_pages}")
    pad = budget_pages - k
    return (np.asarray(page_ids + [0] * pad, np.int32),
            np.asarray(owner + [-1] * pad, np.int32),
            np.asarray(offs + [0] * pad, np.int32))


def read_neighbor_pages(edges: jax.Array, page_ids: jax.Array, page: int):
    """HBM-reader op: fetch the pages listed in ``page_ids``.

    edges is the flat int32 edge array (padded to a page multiple).
    """
    paged = edges.reshape(-1, page)
    return gather_pages(paged, page_ids, interpret=INTERPRET)


def pull_spmv(blocks, block_row, block_col, frontier, num_row_blocks: int):
    """Boolean block SpMV; returns packed OR result as bool[rb, B, L]."""
    row_first = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (block_row[1:] != block_row[:-1]).astype(jnp.int32)])
    acc = pull_spmv_blocks(blocks, block_row, block_col, row_first, frontier,
                           num_row_blocks=num_row_blocks,
                           interpret=INTERPRET)
    return acc > 0
