from repro.kernels.bitmap_update import bitmap_update
from repro.kernels.csr_gather import gather_pages
from repro.kernels.pull_spmv import pull_spmv_blocks
from repro.kernels import ops, ref

__all__ = ["bitmap_update", "gather_pages", "pull_spmv_blocks", "ops", "ref"]
