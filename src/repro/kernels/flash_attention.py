"""Pallas TPU flash-attention kernel (identified §Perf next step).

The pure-JAX chunked flash in models/attention.py materializes its f32
score tiles in HBM on a fusing backend's worst day; this kernel keeps
the [bq, bk] tile, the online-softmax running max/denominator and the
output accumulator in VMEM scratch across the kv grid steps — the HBM
traffic drops to reading Q/K/V once and writing O once (the roofline
floor for attention).

Grid: (batch*heads, q_blocks, kv_blocks); the kv dim iterates fastest on
TPU so the VMEM scratch carries across kv steps of one (bh, qi) cell.
Validated against kernels/ref.flash_attention_ref in interpret mode
(tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  nk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q/k/v: [BH, S, hd] (heads pre-flattened, KV pre-repeated for GQA).

    Returns [BH, S, hd].  Blocks must divide S; hd should be a multiple
    of 128 on real hardware (any size in interpret mode).
    """
    bh, s, hd = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / float(np.sqrt(hd))
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=block_q, bk=block_k,
        nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
